"""Benchmark runner: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run                   # everything
  PYTHONPATH=src python -m benchmarks.run --list            # valid suite names
  PYTHONPATH=src python -m benchmarks.run --only e2e        # one suite
  PYTHONPATH=src python -m benchmarks.run --only e2e,kernel # several suites
  PYTHONPATH=src python -m benchmarks.run --quick           # CPU-sized shapes,
                                                            # seconds not minutes
"""
import argparse
import inspect
import sys
import time
import traceback

SUITES = [
    ("overlap", "benchmarks.overlap_profile"),       # Fig. 2 / Fig. 4
    ("kernel", "benchmarks.kernel_breakdown"),       # Fig. 10
    ("verification", "benchmarks.verification"),     # Fig. 9 / Fig. 7
    ("e2e", "benchmarks.e2e_spec"),                  # Fig. 8
    ("quality", "benchmarks.quality_proxy"),         # Table 1
    ("planner", "benchmarks.planner_eval"),          # Table 3
    ("refinement", "benchmarks.refinement_sweep"),   # Table 4
    ("roofline", "benchmarks.roofline_report"),      # EXPERIMENTS §Roofline
]


def run_suite(modname: str, quick: bool) -> None:
    mod = __import__(modname, fromlist=["main"])
    kwargs = {}
    if quick and "quick" in inspect.signature(mod.main).parameters:
        kwargs["quick"] = True
    mod.main(**kwargs)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names, e.g. --only e2e or "
                         "--only e2e,kernel,quality")
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes/token counts so every suite finishes in "
                         "seconds — the tier-1 smoke-test mode")
    ap.add_argument("--list", action="store_true",
                    help="print the valid suite names (one per line) and exit")
    args = ap.parse_args(argv)
    if args.list:
        for name, _ in SUITES:
            print(name)
        return
    only = None
    if args.only:
        only = [s.strip() for s in args.only.split(",") if s.strip()]
        valid = {n for n, _ in SUITES}
        unknown = [s for s in only if s not in valid]
        if unknown or not only:
            bad = ", ".join(repr(s) for s in unknown) or repr(args.only)
            ap.error(f"unknown suite {bad}; choose from "
                     f"{', '.join(n for n, _ in SUITES)} "
                     "(comma-separate for several, e.g. --only e2e,kernel)")
    print("name,us_per_call,derived")
    failures = 0
    for name, modname in SUITES:
        if only is not None and name not in only:
            continue
        t0 = time.time()
        try:
            run_suite(modname, args.quick)
            print(f"# {name} done in {time.time() - t0:.0f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
