"""Table 1 analogue (no lm-eval harness offline): quality impact of the
approximate shared-index variant and reuse schedules, measured as
  * held-out perplexity of the NSA model under each verification config
    (teacher-forced through verify_step), and
  * greedy output agreement vs the exact baseline.
The paper's claim: approx (C=4) and reuse schedules show negligible
degradation."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.config import ServeConfig, SSVConfig
from repro.core import engine as engine_lib
from repro.core.tree import chain_topology, positions_for
from repro.models import model


def ppl_under(tp, cfg, caches, toks, ssv):
    """Teacher-forced log-loss of the next-token predictions produced by a
    chain verify_step under the given SSV config."""
    topo = chain_topology(toks.shape[1] - 1)
    prefix = caches["length"]
    positions = (jnp.asarray(positions_for(topo, 0))[None] + prefix).astype(jnp.int32)
    tm = jnp.asarray(topo.mask)[None]
    parents = jnp.asarray(topo.parents)
    fn = engine_lib.jit_verify(cfg, ssv)
    logits, _ = fn(tp, caches, toks[:, :topo.num_nodes], positions, tm, parents)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    gold = toks[:, 1:topo.num_nodes + 1]
    ll = jnp.take_along_axis(lp[:, :-1], gold[:, : lp.shape[1] - 1, None], -1)
    return float(jnp.exp(-ll.mean()))


def main(csv=None, quick=False):
    csv = csv or common.Csv("quality")
    tp, cfg, dp, dcfg = common.get_models(train_steps=25 if quick else 80)
    reuse_sched = tuple(range(1, cfg.num_layers, 2))
    held = common.prompts(2 if quick else 4, 160, start=500)
    gen_tokens = 8 if quick else 32

    variants = {
        "ssv_exact": SSVConfig(group_mode="exact", group_size=2),
        "ssv_reuse": SSVConfig(group_mode="exact", group_size=2,
                               refresh_schedule=reuse_sched),
        "ssv_approxC4": SSVConfig(group_mode="approx", group_size=4),
        "ssv_reuse_approxC4": SSVConfig(group_mode="approx", group_size=4,
                                        refresh_schedule=reuse_sched),
    }
    ppls = {k: [] for k in variants}
    for p in held:
        toks = jnp.asarray(p, jnp.int32)[None]
        _, caches = model.prefill(tp, cfg, toks[:, :96], max_len=512)
        for name, ssv in variants.items():
            ppls[name].append(ppl_under(tp, cfg, caches, toks[:, 95:], ssv))
    base = float(np.mean(ppls["ssv_exact"]))
    for name in variants:
        m = float(np.mean(ppls[name]))
        csv.row(f"ppl_{name}", 0.0, f"{m:.3f};delta={100 * (m - base) / base:+.2f}%")

    # greedy output agreement vs exact
    prompt = held[0][:64]
    outs = {}
    for name, ssv in variants.items():
        eng = engine_lib.SSVEngine(tp, cfg, dp, dcfg, ServeConfig(
            max_new_tokens=gen_tokens, temperature=0.0, max_context=512,
            ssv=dataclasses.replace(ssv, tree_depth=3, tree_width=2),
            use_planner=False))
        outs[name] = eng.generate(prompt, max_new_tokens=gen_tokens).tokens
    ref = outs["ssv_exact"]
    for name, o in outs.items():
        m = min(len(ref), len(o))
        agree = float((np.asarray(ref[:m]) == np.asarray(o[:m])).mean())
        csv.row(f"greedy_agreement_{name}", 0.0, f"{agree:.2%}")
    return csv


if __name__ == "__main__":
    main()
