"""Table 4 reproduction: one-factor sensitivity of the runtime-refinement
guard constants (alpha, rho, m, h) around the paper defaults
(alpha=0.40, rho=0.85, m=8, h=5), on a synthetic acceptance process whose
regime shifts mid-request (the situation refinement must catch)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.config import SSVConfig
from repro.core import planner as P


def synthetic_run(pl: P.RuntimePlanner, rng, steps=64):
    """Strategy 0 under-delivers for this 'prompt' (true accept 1.0 vs
    profiled 4.0); strategy 1 delivers 3.0. Reward = accepted/latency."""
    TRUE = {0: (1.0, 0.010), 1: (3.0, 0.011), 2: (2.0, 0.012)}
    total_tok, total_t = 0.0, 0.0
    pl.begin_request(context_len=100)
    for _ in range(steps):
        mean_a, lat = TRUE[min(pl.rank, 2)]
        a = rng.poisson(mean_a)
        pl.observe(accepted=a, latency_s=lat)
        total_tok += a + 1
        total_t += lat
    return total_tok / total_t, pl.refinement_events


def profile():
    entries = [P.ProfileEntry(SSVConfig(tree_depth=3 + i, tree_width=2),
                              4.0 - i * 0.5, 0.01) for i in range(3)]
    return P.Profile(table={(b, pc): list(entries) for b in range(4)
                            for pc in P.PRECISION_CLASSES})


def main(csv=None, quick=False):
    csv = csv or common.Csv("refinement")
    reps = 4 if quick else 16
    prof = profile()
    settings = [("default", {}), ("alpha=0.20", {"alpha": 0.20}),
                ("alpha=0.60", {"alpha": 0.60}), ("rho=0.80", {"rho": 0.80}),
                ("rho=0.90", {"rho": 0.90}), ("m=4", {"warmup_m": 4}),
                ("m=16", {"warmup_m": 16}), ("h=3", {"hysteresis_h": 3}),
                ("h=8", {"hysteresis_h": 8}), ("disabled", {"early_window": 0})]
    rng = np.random.default_rng(0)
    base_tps = None
    for name, kw in settings:
        tps, events = [], 0
        for rep in range(reps):
            pl = P.RuntimePlanner(prof, "Strict", **kw)
            t, e = synthetic_run(pl, np.random.default_rng(rep))
            tps.append(t)
            events += e
        m = float(np.mean(tps))
        if name == "disabled":
            base_tps = m
        csv.row(name.replace("=", ""), 0.0,
                f"tput={m:.0f};events={events}")
    # derived: default beats disabled
    return csv


if __name__ == "__main__":
    main()
