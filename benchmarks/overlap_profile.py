"""Fig. 2 / Fig. 4 reproduction: selected-block overlap between adjacent
verifier queries per layer, and overlap vs token-position distance Δ."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.overlap import adjacent_overlap, pairwise_overlap_by_distance
from repro.models import attention as attn_lib
from repro.models import model, nsa as nsa_lib


def main(csv=None, quick=False):
    csv = csv or common.Csv("overlap")
    tp, cfg, _, _ = common.get_models(train_steps=25 if quick else 80)
    prefix = 192 if quick else 512
    prompt = common.prompts(1, prefix)[0]
    toks = jnp.asarray(prompt, jnp.int32)[None]
    _, caches = model.prefill(tp, cfg, toks, max_len=2 * prefix)
    T = 8 if quick else 16
    positions = jnp.asarray(prefix + np.arange(T))[None]
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, T, cfg.d_model), jnp.float32)

    per_layer = []
    for li in range(cfg.num_layers):
        bp = jax.tree.map(lambda a: a[li], tp["segments"][0][0])
        cache = jax.tree.map(lambda a: a[li], caches["segments"][0][0])
        q, _, _ = attn_lib.qkv(bp["mix"], cfg, x, positions)
        _, p_slc = nsa_lib.routing(bp["mix"], cfg, q, cache["cmp"]["k_cmp"],
                                   cache["cmp"]["v_cmp"], positions,
                                   kv_len=2 * prefix,
                                   ncb_valid=nsa_lib.num_cmp_blocks(prefix, cfg.nsa))
        idx, val = nsa_lib.select_topn(p_slc, positions, prefix, cfg.nsa)
        r = float(np.mean(np.asarray(adjacent_overlap(idx, val))))
        per_layer.append(r)
        csv.row(f"adjacent_overlap_layer{li}", 0.0, f"{r:.3f}")
        if li == 0:
            deltas, by_d = pairwise_overlap_by_distance(idx, val, positions,
                                                        max_delta=8)
            by_d = np.asarray(by_d)
            csv.row("overlap_vs_delta", 0.0,
                    ";".join(f"d{d}={v:.3f}" for d, v in zip(deltas, by_d)))
            # paper claim: overlap decays with distance
            csv.row("overlap_decays", 0.0,
                    str(bool(by_d[0] >= by_d[-1])))
    csv.row("mean_adjacent_overlap", 0.0, f"{np.mean(per_layer):.3f}")
    return csv


if __name__ == "__main__":
    main()
