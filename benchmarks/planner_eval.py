"""Table 3 reproduction (reduced): throughput-aware planning effectiveness.

Base        — fixed EAGLE-style config (BFS, D=3, k=2, exact C=2, all-refresh)
Static-best — top profiled strategy per (bucket, class), no refinement
Best+R      — Static-best + Algorithm-1 runtime refinement

Buckets are context-length ranges scaled to the CPU harness; candidates per
(bucket, class) and generation lengths are reduced (documented here) — the
comparison protocol matches the paper's."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.config import ServeConfig, SSVConfig
from repro.core import engine as engine_lib
from repro.core import planner as P

BUCKETS = ((0, 192), (192, 448))
PROMPT_LEN = {0: 96, 1: 256}
GEN_TOKENS = 32


def run_engine(tp, tcfg, dp, dcfg, prompt, strategy, planner=None, seed=0,
               gen_tokens=GEN_TOKENS):
    # temperature 0.7: stochastic acceptance gives graded, prompt-dependent
    # accept rates — the regime the planner navigates (see common.get_models)
    eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, ServeConfig(
        max_new_tokens=gen_tokens, temperature=0.7, max_context=1024,
        ssv=strategy, use_planner=planner is not None), planner=planner,
        rng_seed=seed)
    res = eng.generate(prompt, max_new_tokens=gen_tokens)
    return res


def candidates(pc, num_layers):
    mode, reuse = P.class_constraints(pc)
    sched = P.default_schedule(num_layers) if reuse else ()
    out = []
    for (D, k, trav) in [(3, 2, "bfs"), (2, 4, "bfs"), (4, 2, "dfs"), (2, 2, "dfs")]:
        out.append(SSVConfig(tree_depth=D, tree_width=k, traversal=trav,
                             group_size=4 if mode == "approx" else 2,
                             group_mode=mode, refresh_schedule=sched,
                             precision_class=pc))
    return out


def main(csv=None, classes=("Strict", "Approx+Reuse"), quick=False):
    csv = csv or common.Csv("planner")
    if quick:
        classes = ("Strict",)
    gen_tokens = 8 if quick else GEN_TOKENS
    buckets = range(1 if quick else len(BUCKETS))
    tp, tcfg, dp, dcfg = common.get_models(train_steps=25 if quick else 80)
    calib = {b: common.prompts(1, PROMPT_LEN[b], start=300 + 10 * b)
             for b in buckets}
    held = {b: common.prompts(1 if quick else 2, PROMPT_LEN[b], start=700 + 10 * b)
            for b in buckets}

    # ---- offline profiling
    table = {}
    for b in buckets:
        for pc in classes:
            entries = []
            cands = candidates(pc, tcfg.num_layers)
            for strat in (cands[:2] if quick else cands):
                res = run_engine(tp, tcfg, dp, dcfg, calib[b][0], strat,
                                 gen_tokens=gen_tokens)
                ea = res.mean_accepted
                et = float(np.mean([s.latency_s for s in res.steps]))
                entries.append(P.ProfileEntry(strat, ea, et))
            entries.sort(key=lambda e: -e.throughput)
            table[(b, pc)] = entries
    profile = P.Profile(table={(b, pc): table[(b, pc)]
                               for b in buckets for pc in classes},
                        buckets=BUCKETS)

    base_strat = SSVConfig(tree_depth=3, tree_width=2, traversal="bfs",
                           group_size=2, group_mode="exact",
                           precision_class="Strict")

    for b in buckets:
        for pc in classes:
            tps = {"base": [], "static": [], "bestR": []}
            rr = False
            for prompt in held[b]:
                r0 = run_engine(tp, tcfg, dp, dcfg, prompt, base_strat,
                                gen_tokens=gen_tokens)
                tps["base"].append(r0.accepted_token_throughput)
                r1 = run_engine(tp, tcfg, dp, dcfg, prompt,
                                profile.table[(b, pc)][0].strategy,
                                gen_tokens=gen_tokens)
                tps["static"].append(r1.accepted_token_throughput)
                pl = P.RuntimePlanner(profile, pc)
                r2 = run_engine(tp, tcfg, dp, dcfg, prompt,
                                profile.table[(b, pc)][0].strategy, planner=pl,
                                gen_tokens=gen_tokens)
                tps["bestR"].append(r2.accepted_token_throughput)
                rr |= pl.refinement_events > 0
            base, static, bestr = (float(np.mean(tps[k]))
                                   for k in ("base", "static", "bestR"))
            gain = 100 * (bestr - base) / max(base, 1e-9)
            csv.row(f"bucket{b}_{pc.replace('+', '_')}", 0.0,
                    f"base={base:.1f};static={static:.1f};bestR={bestr:.1f};"
                    f"gain={gain:+.1f}%;RR={'yes' if rr else 'no'}")
    return csv


if __name__ == "__main__":
    main()
