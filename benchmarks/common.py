"""Shared benchmark substrate: a small NSA target + draft pair TRAINED on the
synthetic corpus (so draft acceptance is non-trivial), cached across bench
invocations in /tmp.

Paper-scale note: the paper benches 1B/8B models at 16K–64K context on H100;
this CPU harness uses a 4-layer NSA model at ≤2K context. All comparisons are
relative (variant vs baseline under identical conditions), mirroring the
paper's methodology at reduced scale.
"""
from __future__ import annotations

import os
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import ckpt
from repro.config import ModelConfig, NSAConfig, ServeConfig, SSVConfig, TrainConfig
from repro.core import draft as draft_lib
from repro.data.synthetic import SyntheticConfig, SyntheticCorpus
from repro.models import model
from repro.runtime.trainer import Trainer

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench")
VOCAB = 256

TARGET_CFG = ModelConfig(
    name="bench-nsa", num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=VOCAB, max_seq_len=4096, dtype="float32",
    attention="nsa",
    nsa=NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4,
                  window=64))
DRAFT_CFG = draft_lib.draft_config(TARGET_CFG, num_layers=1)

DATA_CFG = SyntheticConfig(vocab_size=VOCAB, num_classes=8, seed=11)


def _train(cfg: ModelConfig, steps: int, subdir: str, seed: int):
    tc = TrainConfig(steps=steps, learning_rate=3e-3, warmup_steps=10,
                     checkpoint_every=steps, seed=seed,
                     checkpoint_dir=os.path.join(CACHE_DIR, subdir))
    tr = Trainer(cfg, tc, data_cfg=DATA_CFG, batch_size=8, seq_len=128)
    tr.run()
    return tr.state.params


def get_models(train_steps: int = 80) -> Tuple[dict, ModelConfig, dict, ModelConfig]:
    """(target_params, target_cfg, draft_params, draft_cfg), cached on disk.

    Suites pass train_steps=25 under ``run.py --quick``; the trainer resumes
    from the newest checkpoint in the shared cache, so a longer-trained pair
    is reused as-is and a quick-trained pair is topped up by full runs.

    NOTE on acceptance regimes: at this scale greedy (argmax) agreement
    between target and draft is near-binary — both models trained on the
    same peaky synthetic corpus converge to the same argmax function, so
    greedy acceptance saturates. Greedy benches therefore showcase the
    high-acceptance regime (as the paper's best rows do), while the planner
    benches run at temperature 0.7 where stochastic accept/reject gives
    graded, prompt-dependent acceptance."""
    os.makedirs(CACHE_DIR, exist_ok=True)
    tp = _train(TARGET_CFG, train_steps, "target", seed=0)
    dp = _train(DRAFT_CFG, train_steps, "draft", seed=1)
    return tp, TARGET_CFG, dp, DRAFT_CFG


def corpus() -> SyntheticCorpus:
    return SyntheticCorpus(DATA_CFG)


def prompts(n: int, length: int, start: int = 100):
    c = corpus()
    return [c.batch(start + i, 1, length)[0] for i in range(n)]


def timer(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds per call of a jitted fn (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


class Csv:
    """The ``name,us_per_call,derived`` contract of benchmarks/run.py."""

    def __init__(self, prefix: str):
        self.prefix = prefix
        self.rows = []

    def row(self, name: str, us: float, derived: str = ""):
        self.rows.append((f"{self.prefix}/{name}", us, derived))
        print(f"{self.prefix}/{name},{us:.1f},{derived}", flush=True)
