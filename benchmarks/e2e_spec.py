"""Fig. 8 reproduction: end-to-end generation throughput across draft-tree
shapes (D, k), SSV variants vs the autoregressive NSA decode baseline.

Also the serving-hot-path regression harness:
  * per-step phase breakdown (draft / verify+accept / commit wall time) from
    an instrumented engine run;
  * host-transfer accounting — asserts the spec-decode loop no longer pulls
    the (T, vocab) verification logits to the host (only path tokens /
    counts / bonus cross);
  * batched-vs-sequential aggregate throughput (BatchedSSVEngine with
    batch=R vs R sequential SSVEngine.generate calls);
  * continuous-batching vs drain-then-refill serving (mid-flight slot
    admission over a queued mixed-budget workload, with slot-occupancy and
    queue-delay stats);
  * a BENCH_e2e.json snapshot next to the repo root so the perf trajectory
    is measurable PR over PR.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.config import ServeConfig, SSVConfig
from repro.core import engine as engine_lib
from repro.core import schedule as schedule_lib

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_e2e.json")


def _serve_cfg(ssv, tokens):
    return ServeConfig(max_new_tokens=tokens, temperature=0.0,
                       max_context=1024, ssv=ssv, use_planner=False)


def main(csv=None, grid=((2, 2), (3, 2), (4, 2), (3, 4)), tokens=48,
         quick=False, batch=4):
    csv = csv or common.Csv("e2e")
    if quick:
        grid, tokens, batch = ((2, 2), (3, 2)), 12, 2
    tp, tcfg, dp, dcfg = common.get_models(train_steps=25 if quick else 80)
    prompt = common.prompts(1, 96)[0]
    reuse_sched = tuple(range(1, tcfg.num_layers, 2))
    report = {"tokens": tokens, "grid": [list(g) for g in grid], "variants": {}}

    # autoregressive NSA decode baseline (the paper's 49 tok/s anchor)
    ar = engine_lib.autoregressive_decode(tp, tcfg, prompt, tokens, 1024)
    base_tps = ar.accepted_token_throughput
    csv.row("ar_decode_baseline", 1e6 / max(base_tps, 1e-9), f"{base_tps:.1f}tok/s")
    report["ar_decode_tok_s"] = base_tps

    for (D, k) in grid:
        for variant, sched in (("norefresh", ()), ("reuse", reuse_sched)):
            ssv = SSVConfig(tree_depth=D, tree_width=k, traversal="bfs",
                            group_size=2, group_mode="exact",
                            refresh_schedule=sched)
            eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve_cfg(ssv, tokens))
            res = eng.generate(prompt, max_new_tokens=tokens)
            tps = res.accepted_token_throughput
            # tokens-per-target-pass is the hardware-transferable gain: on
            # memory-bound accelerators step latency is ~flat in gamma
            # (paper Fig. 7), so emitted-per-pass bounds the speedup there.
            per_pass = res.mean_accepted + 1.0
            csv.row(f"D{D}_k{k}_{variant}",
                    1e6 / max(tps, 1e-9),
                    f"{tps:.1f}tok/s;speedup={tps / max(base_tps, 1e-9):.2f}x;"
                    f"acc={res.mean_accepted:.2f};tok_per_pass={per_pass:.2f}")
            report["variants"][f"D{D}_k{k}_{variant}"] = {
                "tok_s": tps, "speedup_vs_ar": tps / max(base_tps, 1e-9),
                "mean_accepted": res.mean_accepted}

    # ---- per-step phase breakdown (instrumented run: sync between phases)
    ssv0 = SSVConfig(tree_depth=grid[0][0], tree_width=grid[0][1],
                     traversal="bfs", group_size=2, group_mode="exact")
    eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve_cfg(ssv0, tokens),
                               instrument=True)
    res = eng.generate(prompt, max_new_tokens=tokens)
    phases = {}
    for st in res.steps[1:] or res.steps:     # drop the compile step
        for k2, v in (st.phases or {}).items():
            phases.setdefault(k2, []).append(v)
    breakdown = {k2: float(np.mean(v)) for k2, v in phases.items()}
    for k2, v in breakdown.items():
        csv.row(f"step_phase_{k2}", v * 1e6, "mean per-step seconds (instrumented)")
    report["step_phase_breakdown_s"] = breakdown

    # ---- host-transfer accounting: the fused step returns a few ints, not
    # (T, vocab) logits
    T = ssv0.num_draft_tokens() + 1
    per_step = engine_lib.step_host_transfer_elems(ssv0)
    logits_elems = T * tcfg.vocab_size
    assert per_step < logits_elems, (
        f"spec-decode step transfers {per_step} elems/step — expected far "
        f"fewer than the {logits_elems} of a (T, vocab) logits pull")
    observed = max(s.host_elems for s in res.steps)
    assert observed < logits_elems
    csv.row("host_transfer_elems_per_step", float(per_step),
            f"vs_logits={logits_elems};ratio={per_step / logits_elems:.5f}")
    report["host_transfer"] = {"elems_per_step": per_step,
                               "logits_elems": logits_elems}

    # ---- batched vs sequential serving throughput
    prompts = common.prompts(batch, 96, start=200)
    seq_t0 = time.time()
    seq_tokens = 0
    for p in prompts:
        e = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve_cfg(ssv0, tokens))
        seq_tokens += len(e.generate(p, max_new_tokens=tokens).tokens)
    seq_dt = time.time() - seq_t0
    seq_tps = seq_tokens / max(seq_dt, 1e-9)

    beng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve_cfg(ssv0, tokens))
    beng.generate_batch(prompts, max_new_tokens=tokens)   # warm the jit
    bres = beng.generate_batch(prompts, max_new_tokens=tokens)
    bat_tps = bres.aggregate_throughput
    csv.row(f"serve_sequential_x{batch}", 1e6 / max(seq_tps, 1e-9),
            f"{seq_tps:.1f}tok/s_aggregate")
    csv.row(f"serve_batched_x{batch}", 1e6 / max(bat_tps, 1e-9),
            f"{bat_tps:.1f}tok/s_aggregate;"
            f"speedup_vs_sequential={bat_tps / max(seq_tps, 1e-9):.2f}x")
    report["serving"] = {"batch": batch,
                         "sequential_tok_s": seq_tps,
                         "batched_tok_s": bat_tps,
                         "batched_speedup": bat_tps / max(seq_tps, 1e-9)}

    # ---- continuous batching vs drain-then-refill
    # A realistic serving mix: 2*batch queued requests, each drain wave
    # carrying one straggler (full token budget) among short jobs.
    # Drain-then-refill holds every freed slot hostage until the wave's
    # straggler finishes; continuous batching admits the next queued request
    # into a slot the moment it frees (per-slot re-prefill mid-flight). Same
    # engine, same fused step, same per-request budgets — the only variable
    # is the slot admission policy.
    n_req = 2 * batch
    cont_prompts = common.prompts(n_req, 96, start=300)
    budgets = [tokens if i % batch == 0 else max(4, tokens // 4)
               for i in range(n_req)]

    def _reqs(lo, hi):
        return [schedule_lib.Request(req_id=i, prompt=cont_prompts[i],
                                     max_new_tokens=budgets[i], arrival=0.0)
                for i in range(lo, hi)]

    def _drain():
        tok, steps, wall = 0, 0, 0.0
        for lo in range(0, n_req, batch):
            eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg,
                                              _serve_cfg(ssv0, tokens))
            r = eng.serve_continuous(_reqs(lo, min(lo + batch, n_req)),
                                     num_slots=batch)
            tok += r.total_tokens
            steps += r.steps
            wall += r.wall_s
        return tok, steps, wall

    def _continuous():
        eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg,
                                          _serve_cfg(ssv0, tokens))
        return eng.serve_continuous(_reqs(0, n_req), num_slots=batch)

    _drain(); _continuous()                     # warm the jit caches
    # best-of-2: a single timed pass is noisy on shared CPU runners
    d_tok, d_steps, d_wall = min((_drain() for _ in range(2)),
                                 key=lambda r: r[2])
    cres = min((_continuous() for _ in range(2)), key=lambda r: r.wall_s)
    drain_tps = d_tok / max(d_wall, 1e-9)
    cont_tps = cres.aggregate_throughput
    csv.row(f"serve_drain_refill_x{batch}", 1e6 / max(drain_tps, 1e-9),
            f"{drain_tps:.1f}tok/s_aggregate;fused_steps={d_steps}")
    csv.row(f"serve_continuous_x{batch}", 1e6 / max(cont_tps, 1e-9),
            f"{cont_tps:.1f}tok/s_aggregate;fused_steps={cres.steps};"
            f"occupancy={cres.mean_occupancy:.2f};"
            f"speedup_vs_drain={cont_tps / max(drain_tps, 1e-9):.2f}x")
    report["continuous"] = {
        "batch": batch, "requests": n_req,
        "drain_tok_s": drain_tps, "continuous_tok_s": cont_tps,
        "speedup_vs_drain": cont_tps / max(drain_tps, 1e-9),
        "drain_fused_steps": d_steps, "continuous_fused_steps": cres.steps,
        "mean_occupancy": cres.mean_occupancy,
        "mean_queue_delay_steps": cres.mean_queue_delay_steps}

    # quick mode goes to /tmp: the committed baseline only tracks full runs
    path = "/tmp/BENCH_e2e.quick.json" if quick else BENCH_JSON
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    csv.row("bench_json", 0.0, os.path.abspath(path))
    return csv


if __name__ == "__main__":
    main()
