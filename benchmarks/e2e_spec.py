"""Fig. 8 reproduction: end-to-end generation throughput across draft-tree
shapes (D, k), SSV variants vs the autoregressive NSA decode baseline.

Also the serving-hot-path regression harness:
  * per-step phase breakdown (draft / verify+accept / commit wall time) from
    an instrumented engine run;
  * host-transfer accounting — asserts the spec-decode loop no longer pulls
    the (T, vocab) verification logits to the host (only path tokens /
    counts / bonus cross);
  * batched-vs-sequential aggregate throughput (BatchedSSVEngine with
    batch=R vs R sequential SSVEngine.generate calls);
  * continuous-batching vs drain-then-refill serving (mid-flight slot
    admission over a queued mixed-budget workload, with slot-occupancy and
    queue-delay stats);
  * a BENCH_e2e.json snapshot next to the repo root so the perf trajectory
    is measurable PR over PR.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.config import ServeConfig, SSVConfig
from repro.core import engine as engine_lib
from repro.core import planner as planner_lib
from repro.core import schedule as schedule_lib

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_e2e.json")


def _serve_cfg(ssv, tokens):
    return ServeConfig(max_new_tokens=tokens, temperature=0.0,
                       max_context=1024, ssv=ssv, use_planner=False)


def main(csv=None, grid=((2, 2), (3, 2), (4, 2), (3, 4)), tokens=48,
         quick=False, batch=4):
    csv = csv or common.Csv("e2e")
    if quick:
        grid, tokens, batch = ((2, 2), (3, 2)), 12, 2
    tp, tcfg, dp, dcfg = common.get_models(train_steps=25 if quick else 80)
    prompt = common.prompts(1, 96)[0]
    reuse_sched = tuple(range(1, tcfg.num_layers, 2))
    report = {"tokens": tokens, "grid": [list(g) for g in grid], "variants": {}}

    # autoregressive NSA decode baseline (the paper's 49 tok/s anchor)
    ar = engine_lib.autoregressive_decode(tp, tcfg, prompt, tokens, 1024)
    base_tps = ar.accepted_token_throughput
    csv.row("ar_decode_baseline", 1e6 / max(base_tps, 1e-9), f"{base_tps:.1f}tok/s")
    report["ar_decode_tok_s"] = base_tps

    for (D, k) in grid:
        for variant, sched in (("norefresh", ()), ("reuse", reuse_sched)):
            ssv = SSVConfig(tree_depth=D, tree_width=k, traversal="bfs",
                            group_size=2, group_mode="exact",
                            refresh_schedule=sched)
            eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve_cfg(ssv, tokens))
            res = eng.generate(prompt, max_new_tokens=tokens)
            tps = res.accepted_token_throughput
            # tokens-per-target-pass is the hardware-transferable gain: on
            # memory-bound accelerators step latency is ~flat in gamma
            # (paper Fig. 7), so emitted-per-pass bounds the speedup there.
            per_pass = res.mean_accepted + 1.0
            csv.row(f"D{D}_k{k}_{variant}",
                    1e6 / max(tps, 1e-9),
                    f"{tps:.1f}tok/s;speedup={tps / max(base_tps, 1e-9):.2f}x;"
                    f"acc={res.mean_accepted:.2f};tok_per_pass={per_pass:.2f}")
            report["variants"][f"D{D}_k{k}_{variant}"] = {
                "tok_s": tps, "speedup_vs_ar": tps / max(base_tps, 1e-9),
                "mean_accepted": res.mean_accepted}

    # ---- per-step phase breakdown (instrumented run: sync between phases)
    ssv0 = SSVConfig(tree_depth=grid[0][0], tree_width=grid[0][1],
                     traversal="bfs", group_size=2, group_mode="exact")
    eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve_cfg(ssv0, tokens),
                               instrument=True)
    res = eng.generate(prompt, max_new_tokens=tokens)
    phases = {}
    for st in res.steps[1:] or res.steps:     # drop the compile step
        for k2, v in (st.phases or {}).items():
            phases.setdefault(k2, []).append(v)
    breakdown = {k2: float(np.mean(v)) for k2, v in phases.items()}
    for k2, v in breakdown.items():
        csv.row(f"step_phase_{k2}", v * 1e6, "mean per-step seconds (instrumented)")
    report["step_phase_breakdown_s"] = breakdown

    # ---- host-transfer accounting: the fused step returns a few ints, not
    # (T, vocab) logits
    T = ssv0.num_draft_tokens() + 1
    per_step = engine_lib.step_host_transfer_elems(ssv0)
    logits_elems = T * tcfg.vocab_size
    assert per_step < logits_elems, (
        f"spec-decode step transfers {per_step} elems/step — expected far "
        f"fewer than the {logits_elems} of a (T, vocab) logits pull")
    observed = max(s.host_elems for s in res.steps)
    assert observed < logits_elems
    csv.row("host_transfer_elems_per_step", float(per_step),
            f"vs_logits={logits_elems};ratio={per_step / logits_elems:.5f}")
    report["host_transfer"] = {"elems_per_step": per_step,
                               "logits_elems": logits_elems}

    # ---- batched vs sequential serving throughput
    prompts = common.prompts(batch, 96, start=200)
    seq_t0 = time.time()
    seq_tokens = 0
    for p in prompts:
        e = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve_cfg(ssv0, tokens))
        seq_tokens += len(e.generate(p, max_new_tokens=tokens).tokens)
    seq_dt = time.time() - seq_t0
    seq_tps = seq_tokens / max(seq_dt, 1e-9)

    beng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve_cfg(ssv0, tokens))
    beng.generate_batch(prompts, max_new_tokens=tokens)   # warm the jit
    bres = beng.generate_batch(prompts, max_new_tokens=tokens)
    bat_tps = bres.aggregate_throughput
    bat_kv = beng.kv_cache_bytes()
    csv.row(f"serve_sequential_x{batch}", 1e6 / max(seq_tps, 1e-9),
            f"{seq_tps:.1f}tok/s_aggregate")
    csv.row(f"serve_batched_x{batch}", 1e6 / max(bat_tps, 1e-9),
            f"{bat_tps:.1f}tok/s_aggregate;"
            f"speedup_vs_sequential={bat_tps / max(seq_tps, 1e-9):.2f}x;"
            f"peak_kv_bytes={bat_kv}")
    report["serving"] = {"batch": batch,
                         "sequential_tok_s": seq_tps,
                         "batched_tok_s": bat_tps,
                         "batched_speedup": bat_tps / max(seq_tps, 1e-9),
                         "peak_kv_bytes": bat_kv}

    # ---- continuous batching vs drain-then-refill
    # A realistic serving mix: 2*batch queued requests, each drain wave
    # carrying one straggler (full token budget) among short jobs.
    # Drain-then-refill holds every freed slot hostage until the wave's
    # straggler finishes; continuous batching admits the next queued request
    # into a slot the moment it frees (per-slot re-prefill mid-flight). Same
    # engine, same fused step, same per-request budgets — the only variable
    # is the slot admission policy.
    n_req = 2 * batch
    cont_prompts = common.prompts(n_req, 96, start=300)
    budgets = [tokens if i % batch == 0 else max(4, tokens // 4)
               for i in range(n_req)]

    def _reqs(lo, hi):
        return [schedule_lib.Request(req_id=i, prompt=cont_prompts[i],
                                     max_new_tokens=budgets[i], arrival=0.0)
                for i in range(lo, hi)]

    def _drain():
        tok, steps, wall = 0, 0, 0.0
        for lo in range(0, n_req, batch):
            eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg,
                                              _serve_cfg(ssv0, tokens))
            r = eng.serve_continuous(_reqs(lo, min(lo + batch, n_req)),
                                     num_slots=batch)
            tok += r.total_tokens
            steps += r.steps
            wall += r.wall_s
        return tok, steps, wall

    def _continuous():
        eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg,
                                          _serve_cfg(ssv0, tokens))
        return eng.serve_continuous(_reqs(0, n_req), num_slots=batch)

    _drain(); _continuous()                     # warm the jit caches
    # best-of-2: a single timed pass is noisy on shared CPU runners
    d_tok, d_steps, d_wall = min((_drain() for _ in range(2)),
                                 key=lambda r: r[2])
    cres = min((_continuous() for _ in range(2)), key=lambda r: r.wall_s)
    drain_tps = d_tok / max(d_wall, 1e-9)
    cont_tps = cres.aggregate_throughput
    csv.row(f"serve_drain_refill_x{batch}", 1e6 / max(drain_tps, 1e-9),
            f"{drain_tps:.1f}tok/s_aggregate;fused_steps={d_steps}")
    csv.row(f"serve_continuous_x{batch}", 1e6 / max(cont_tps, 1e-9),
            f"{cont_tps:.1f}tok/s_aggregate;fused_steps={cres.steps};"
            f"occupancy={cres.mean_occupancy:.2f};"
            f"speedup_vs_drain={cont_tps / max(drain_tps, 1e-9):.2f}x;"
            f"peak_kv_bytes={cres.kv_bytes}")
    report["continuous"] = {
        "batch": batch, "requests": n_req,
        "drain_tok_s": drain_tps, "continuous_tok_s": cont_tps,
        "speedup_vs_drain": cont_tps / max(drain_tps, 1e-9),
        "drain_fused_steps": d_steps, "continuous_fused_steps": cres.steps,
        "mean_occupancy": cres.mean_occupancy,
        "mean_queue_delay_steps": cres.mean_queue_delay_steps,
        "peak_kv_bytes": cres.kv_bytes}

    # ---- paged vs dense KV store (low-occupancy continuous workload)
    # Mixed-length, mixed-budget requests over max_context-sized slots: the
    # dense layout allocates slots x max_context x layers KV rows no matter
    # what's live; the paged store provisions only each request's page
    # reservation (prompt + budget + speculative headroom), so at low
    # occupancy its peak KV bytes drop with the workload. Token equality
    # between the backends is asserted here on top of the dedicated tests.
    kv_prompts = [common.prompts(1, 64 + 32 * (i % 3), start=400 + i)[0]
                  for i in range(n_req)]
    kv_budgets = [max(4, tokens // (1 + i % 3)) for i in range(n_req)]

    def _kv_reqs():
        return [schedule_lib.Request(req_id=i, prompt=kv_prompts[i],
                                     max_new_tokens=kv_budgets[i], arrival=0.0)
                for i in range(n_req)]

    def _kv_serve(backend, num_pages=0):
        return ServeConfig(max_new_tokens=tokens, temperature=0.0,
                           max_context=1024, ssv=ssv0, use_planner=False,
                           kv_backend=backend, kv_num_pages=num_pages)

    sizer = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _kv_serve("paged"))
    needs = sorted(sizer.pages_for(len(p), b)
                   for p, b in zip(kv_prompts, kv_budgets))
    pool_pages = sum(needs[-batch:])          # full slot concurrency, no waits

    def _kv_run(backend):
        eng = engine_lib.BatchedSSVEngine(
            tp, tcfg, dp, dcfg,
            _kv_serve(backend, pool_pages if backend == "paged" else 0))
        eng.serve_continuous(_kv_reqs(), num_slots=batch)        # warm the jit
        res = min((eng.serve_continuous(_kv_reqs(), num_slots=batch)
                   for _ in range(2)), key=lambda r: r.wall_s)
        return eng, res

    _, kv_dense = _kv_run("dense")
    _, kv_paged = _kv_run("paged")
    for rd, rp in zip(kv_dense.results, kv_paged.results):
        assert np.array_equal(rd.tokens, rp.tokens), \
            "paged backend diverged from dense on the serving workload"
    assert kv_paged.kv_bytes < kv_dense.kv_bytes, (
        f"paged KV footprint {kv_paged.kv_bytes} not below dense "
        f"{kv_dense.kv_bytes} on the low-occupancy workload")
    ratio = kv_paged.kv_bytes / max(kv_dense.kv_bytes, 1)
    tput_ratio = kv_paged.aggregate_throughput / max(
        kv_dense.aggregate_throughput, 1e-9)
    csv.row(f"serve_kv_dense_x{batch}",
            1e6 / max(kv_dense.aggregate_throughput, 1e-9),
            f"{kv_dense.aggregate_throughput:.1f}tok/s_aggregate;"
            f"peak_kv_bytes={kv_dense.kv_bytes}")
    csv.row(f"serve_kv_paged_x{batch}",
            1e6 / max(kv_paged.aggregate_throughput, 1e-9),
            f"{kv_paged.aggregate_throughput:.1f}tok/s_aggregate;"
            f"peak_kv_bytes={kv_paged.kv_bytes};bytes_vs_dense={ratio:.2f};"
            f"tput_vs_dense={tput_ratio:.2f};"
            f"page_occ={kv_paged.mean_page_occupancy:.2f}")
    report["kv_store"] = {
        "batch": batch, "requests": n_req, "pool_pages": pool_pages,
        # fraction of the dense layout's token capacity the workload can
        # ever occupy — the low-occupancy regime where paging pays
        "dense_capacity_utilization":
            pool_pages * sizer._page_size / (batch * 1024),
        "dense_tok_s": kv_dense.aggregate_throughput,
        "paged_tok_s": kv_paged.aggregate_throughput,
        "throughput_ratio": tput_ratio,
        "dense_peak_kv_bytes": kv_dense.kv_bytes,
        "paged_peak_kv_bytes": kv_paged.kv_bytes,
        "kv_bytes_ratio": ratio,
        "mean_occupancy": kv_paged.mean_occupancy,
        "mean_page_occupancy": kv_paged.mean_page_occupancy,
        "peak_page_occupancy": kv_paged.peak_page_occupancy,
        "token_equal": True}

    # ---- bucket-local vs shared-strategy mixed-length serving
    # The paper's third pillar at batch scale: a mixed-length continuous
    # batch under ONE shared strategy runs its short-context rows on the
    # long-context tree topology (today's planner picks by max context), so
    # every short-row step verifies a deep tree it cannot fill. Bucket-local
    # execution groups give each context regime its profile strategy —
    # short rows step a shallow tree, long rows keep the deep one — with
    # per-request token streams byte-identical to single-stream generation
    # under the row's bucket strategy (asserted below).
    buckets = ((0, 64), (64, 4096))
    short_strat = SSVConfig(tree_depth=1, tree_width=2, traversal="bfs",
                            group_size=2, group_mode="exact")
    long_strat = SSVConfig(tree_depth=4, tree_width=2, traversal="bfs",
                           group_size=2, group_mode="exact")
    # expected_accept 0.0: the runtime guard never refines, so strategies —
    # and therefore tokens — are deterministic for the equality check
    profile = planner_lib.Profile(
        table={(0, "Strict"): [planner_lib.ProfileEntry(short_strat, 0.0, 1.0)],
               (1, "Strict"): [planner_lib.ProfileEntry(long_strat, 0.0, 1.0)]},
        buckets=buckets)
    n_short = 2 * batch
    n_long = max(1, batch // 2)
    mixed = ([common.prompts(1, 24, start=500 + i)[0] for i in range(n_short)]
             + [common.prompts(1, 96, start=600 + i)[0] for i in range(n_long)])
    mixed_budgets = ([max(4, tokens // 4)] * n_short + [tokens] * n_long)

    def _mixed_reqs():
        return [schedule_lib.Request(req_id=i, prompt=mixed[i],
                                     max_new_tokens=mixed_budgets[i],
                                     arrival=0.0)
                for i in range(len(mixed))]

    # per-request ground truth: single-stream generation under the strategy
    # the profile assigns to that request's bucket
    bucket_refs = []
    for p, b in zip(mixed, mixed_budgets):
        strat = (short_strat if planner_lib.bucket_of(len(p), buckets) == 0
                 else long_strat)
        e = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve_cfg(strat, tokens))
        bucket_refs.append(e.generate(p, max_new_tokens=b).tokens)

    def _shared():
        # the shared-strategy baseline: what today's batch planner runs —
        # one strategy keyed on the batch's max context, i.e. the deep tree
        eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg,
                                          _serve_cfg(long_strat, tokens))
        return eng.serve_continuous(_mixed_reqs(), num_slots=batch)

    def _bucketed():
        eng = engine_lib.BatchedSSVEngine(
            tp, tcfg, dp, dcfg, _serve_cfg(long_strat, tokens),
            planner=planner_lib.BatchPlanner(profile, "Strict"))
        return eng, eng.serve_continuous(_mixed_reqs(), num_slots=batch,
                                         warmup=True)
    _shared(); beng, _ = _bucketed()            # warm the jit / AOT caches
    sres = min((_shared() for _ in range(2)), key=lambda r: r.wall_s)
    bres = min((beng.serve_continuous(_mixed_reqs(), num_slots=batch)
                for _ in range(2)), key=lambda r: r.wall_s)
    for ref, gen in zip(bucket_refs, bres.results):
        assert np.array_equal(ref, gen.tokens), (
            "bucketed serving diverged from single-stream generation under "
            "the row's bucket strategy")
    shared_tps = sres.aggregate_throughput
    buck_tps = bres.aggregate_throughput
    csv.row(f"serve_shared_strategy_x{batch}", 1e6 / max(shared_tps, 1e-9),
            f"{shared_tps:.1f}tok/s_aggregate;fused_steps={sres.steps}")
    csv.row(f"serve_bucketed_x{batch}", 1e6 / max(buck_tps, 1e-9),
            f"{buck_tps:.1f}tok/s_aggregate;"
            f"speedup_vs_shared={buck_tps / max(shared_tps, 1e-9):.2f}x;"
            f"group_launches={bres.group_launches};"
            f"step_cache_misses={bres.kernel_cache['step_cache_misses']}")
    report["bucketed"] = {
        "slots": batch, "requests": len(mixed),
        "n_short": n_short, "n_long": n_long,
        "shared_tok_s": shared_tps, "bucketed_tok_s": buck_tps,
        "speedup_vs_shared": buck_tps / max(shared_tps, 1e-9),
        "shared_fused_steps": sres.steps, "bucketed_fused_steps": bres.steps,
        "group_launches": bres.group_launches,
        "bucket_occupancy": {str(k): v
                             for k, v in bres.bucket_occupancy.items()},
        "step_cache": {k: v for k, v in bres.kernel_cache.items()
                       if k.startswith("step_cache")},
        "token_equal": True}

    # quick mode goes to /tmp: the committed baseline only tracks full runs
    path = "/tmp/BENCH_e2e.quick.json" if quick else BENCH_JSON
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    csv.row("bench_json", 0.0, os.path.abspath(path))
    return csv


if __name__ == "__main__":
    main()
