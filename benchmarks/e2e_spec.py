"""Fig. 8 reproduction: end-to-end generation throughput across draft-tree
shapes (D, k), SSV variants vs the autoregressive NSA decode baseline."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.config import ServeConfig, SSVConfig
from repro.core import engine as engine_lib


def main(csv=None, grid=((2, 2), (3, 2), (4, 2), (3, 4)), tokens=48):
    csv = csv or common.Csv("e2e")
    tp, tcfg, dp, dcfg = common.get_models()
    prompt = common.prompts(1, 96)[0]
    reuse_sched = tuple(range(1, tcfg.num_layers, 2))

    # autoregressive NSA decode baseline (the paper's 49 tok/s anchor)
    ar = engine_lib.autoregressive_decode(tp, tcfg, prompt, tokens, 1024)
    base_tps = ar.accepted_token_throughput
    csv.row("ar_decode_baseline", 1e6 / max(base_tps, 1e-9), f"{base_tps:.1f}tok/s")

    for (D, k) in grid:
        for variant, sched in (("norefresh", ()), ("reuse", reuse_sched)):
            ssv = SSVConfig(tree_depth=D, tree_width=k, traversal="bfs",
                            group_size=2, group_mode="exact",
                            refresh_schedule=sched)
            eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, ServeConfig(
                max_new_tokens=tokens, temperature=0.0, max_context=1024,
                ssv=ssv, use_planner=False))
            res = eng.generate(prompt, max_new_tokens=tokens)
            tps = res.accepted_token_throughput
            # tokens-per-target-pass is the hardware-transferable gain: on
            # memory-bound accelerators step latency is ~flat in gamma
            # (paper Fig. 7), so emitted-per-pass bounds the speedup there.
            per_pass = res.mean_accepted + 1.0
            csv.row(f"D{D}_k{k}_{variant}",
                    1e6 / max(tps, 1e-9),
                    f"{tps:.1f}tok/s;speedup={tps / max(base_tps, 1e-9):.2f}x;"
                    f"acc={res.mean_accepted:.2f};tok_per_pass={per_pass:.2f}")
    return csv


if __name__ == "__main__":
    main()
