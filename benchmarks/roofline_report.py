"""Roofline table from the dry-run artifacts (§Roofline of EXPERIMENTS.md).

Reads artifacts/dryrun/<mesh>/*.json and emits one row per (arch × shape):
three terms in seconds, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs useful
ratio, roofline fraction, and HBM fit."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(mesh: str = "single"):
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            rows.append(rec)
        else:
            rows.append(rec)
    return rows


def main(csv=None, mesh: str = "single"):
    from benchmarks.common import Csv
    csv = csv or Csv(f"roofline_{mesh}")
    rows = load(mesh)
    if not rows:
        csv.row("missing", 0.0, "run launch/dryrun.py first")
        return csv
    for rec in rows:
        name = f"{rec['arch']}__{rec['shape']}"
        if rec.get("status") != "ok":
            csv.row(name, 0.0, f"FAILED:{rec.get('error', '')[:80]}")
            continue
        r = rec["roofline"]
        csv.row(name, r["compute_s"] * 1e6 if r else 0.0,
                f"cmp={r['compute_s']:.4f}s;mem={r['memory_s']:.4f}s;"
                f"coll={r['collective_s']:.4f}s;bneck={r['bottleneck']};"
                f"useful={r['useful_ratio']:.3f};"
                f"roofline={r['roofline_fraction']:.3f};"
                f"fits={r['fits_hbm']}")
    return csv


if __name__ == "__main__":
    main()
