"""Fig. 10 reproduction: SSV kernel variants breakdown.

Two measurement axes (CPU container — no TPU wall clock):
  * STRUCTURAL: HBM bytes + kernel-launch counts per variant, derived from
    the execution plan (unique-block loads under exact/approx grouping at
    overlap s, branch materialization under vanilla/refresh/reuse fusion) —
    the quantities the paper's kernel speedups come from;
  * MEASURED: interpret-mode Pallas wall time — the interpreter executes one
    python step per (grid cell × work item), so relative time tracks the
    work-item count (loads+launches) the fusion eliminates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.config import NSAConfig
from repro.kernels.nsa_verify import ops


def synth_indices(rng, B, T, Hkv, n, nblocks, s):
    """Adjacent-query selected sets with controlled overlap s (paper Fig. 10
    sweeps s = |I_t ∩ I_{t-1}|)."""
    idx = np.zeros((B, T, Hkv, n), np.int64)
    for b in range(B):
        for h in range(Hkv):
            cur = rng.choice(nblocks, size=n, replace=False)
            idx[b, 0, h] = np.sort(cur)
            for t in range(1, T):
                keep = rng.choice(cur, size=min(s, n), replace=False)
                pool = np.setdiff1d(np.arange(nblocks), keep)
                new = rng.choice(pool, size=n - len(keep), replace=False)
                cur = np.concatenate([keep, new])
                idx[b, t, h] = np.sort(cur)
    return jnp.asarray(idx, jnp.int32)


def structural_metrics(nsa: NSAConfig, idx, valid, C, mode, fusion):
    """(hbm_block_bytes, launches, index_builds) per verification pass."""
    B, T, Hkv, n = idx.shape
    from repro.core import overlap as ov
    if mode == "none":
        loads = int(np.asarray(valid).sum())
    elif mode == "exact":
        _, _, mval = ov.merged_schedule(idx, valid, C)
        loads = int(np.asarray(mval).sum())
    else:
        i2, v2 = ov.shared_index(idx, valid, jnp.arange(T)[None].repeat(B, 0), C)
        G = -(-T // C)
        loads = int(np.asarray(v2[:, ::C][:, :G]).sum())
    launches = {"vanilla": 4, "refresh": 2, "reuse": 1}[fusion]
    index_builds = 0 if fusion == "reuse" else 1
    return loads, launches, index_builds


def main(csv=None, quick=False):
    csv = csv or common.Csv("kernel")
    nsa = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=8,
                    window=64)
    rng = np.random.default_rng(0)
    B, Hkv, Dh, Hq = 1, 2, 32, 4
    S = 512 if quick else 1024
    nblocks = S // nsa.sel_block
    prefix = S - 64

    for gamma in ((4,) if quick else (4, 16)):
        T = gamma
        for s in ((4,) if quick else (2, 4, 6)):
            idx = synth_indices(rng, B, T, Hkv, nsa.n_selected, prefix // nsa.sel_block, s)
            valid = jnp.ones(idx.shape, bool)
            base = None
            for mode, C, fusion in [("none", 1, "vanilla"), ("none", 1, "refresh"),
                                    ("none", 1, "reuse"), ("exact", 2, "reuse"),
                                    ("approx", 4, "reuse")]:
                loads, launches, builds = structural_metrics(nsa, idx, valid, C,
                                                             mode, fusion)
                blk_bytes = loads * nsa.sel_block * Dh * 4
                # branch-output materialization traffic (vanilla writes 3
                # branch outputs + reads them back; refresh 1; reuse 0)
                mat = {"vanilla": 3, "refresh": 1, "reuse": 0}[fusion]
                mat_bytes = mat * 2 * T * Hq * Dh * 4
                total = blk_bytes + mat_bytes
                name = f"g{gamma}_s{s}_{fusion}_{mode}C{C}"
                if base is None:
                    base = total
                csv.row(name, 0.0,
                        f"blocks={loads};launches={launches};idx_builds={builds};"
                        f"bytes={total};traffic_ratio={base / total:.2f}x")
    # interpret-mode relative timing (small shapes; relative only)
    rngj = np.random.default_rng(1)

    def r(*shape):
        return jnp.asarray(rngj.normal(size=shape), jnp.float32)
    T = 8
    kc, vc = r(B, 256, Hkv, Dh), r(B, 256, Hkv, Dh)
    ncb = (256 - nsa.cmp_block) // nsa.cmp_stride + 1
    kcmp, vcmp = r(B, ncb, Hkv, Dh), r(B, ncb, Hkv, Dh)
    kd, vd = r(B, T, Hkv, Dh), r(B, T, Hkv, Dh)
    q = r(B, T, Hq, Dh) / np.sqrt(Dh)
    gates = jax.nn.sigmoid(r(B, T, 3, Hq))
    positions = jnp.asarray(200 + np.arange(T))[None]
    tm = jnp.asarray(np.tril(np.ones((T, T), bool)))[None]
    idx = synth_indices(rngj, B, T, Hkv, nsa.n_selected, 200 // nsa.sel_block, 4)
    valid = jnp.ones(idx.shape, bool)
    import time as _t
    for label, kwargs in [
            ("interp_ungrouped", dict(C=1, mode="exact")),
            ("interp_exactC2", dict(C=2, mode="exact")),
            ("interp_approxC4", dict(C=4, mode="approx"))]:
        t0 = _t.perf_counter()
        out = ops.nsa_verify_fused(q, kc, vc, kcmp, vcmp, kd, vd, idx, valid,
                                   positions, 200, (200 - 8) // 4 + 1, tm,
                                   gates, nsa, **kwargs)
        jax.block_until_ready(out)
        csv.row(label, (_t.perf_counter() - t0) * 1e6, "")
    return csv


if __name__ == "__main__":
    main()
