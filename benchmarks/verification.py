"""Fig. 9 / Fig. 7 reproduction: verification-stage latency across draft
lengths gamma and context lengths N, for SSV variants (no-reuse / reuse ×
exact C=2 / approx C=4), vs the dense-verification baseline.

Latencies are real wall-clock of the jitted XLA verification step on CPU —
relative ordering between variants is the measured quantity (absolute H100
numbers are out of scope; see benchmarks/common.py)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.config import SSVConfig
from repro.core import engine as engine_lib
from repro.core.tree import chain_topology, positions_for
from repro.models import model


def bench_verify(tp, cfg, caches, gamma: int, ssv, csv, label):
    topo = chain_topology(gamma)
    T = topo.num_nodes
    prefix = caches["length"]
    positions = (jnp.asarray(positions_for(topo, 0))[None] + prefix).astype(jnp.int32)
    tm = jnp.asarray(topo.mask)[None]
    parents = jnp.asarray(topo.parents)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size,
                                                         (1, T)), jnp.int32)
    fn = engine_lib.jit_verify(cfg, ssv)
    t = common.timer(lambda: fn(tp, caches, toks, positions, tm, parents))
    csv.row(label, t * 1e6, f"gamma={gamma}")
    return t


def main(csv=None, sweep_gamma=(4, 16, 32), contexts=(512, 1024), quick=False):
    csv = csv or common.Csv("verification")
    if quick:
        sweep_gamma, contexts = (4,), (256,)
    tp, cfg, _, _ = common.get_models(train_steps=25 if quick else 80)
    reuse_sched = tuple(range(1, cfg.num_layers, 2))  # paper: alternating

    for N in contexts:
        prompt = common.prompts(1, N)[0]
        toks = jnp.asarray(prompt, jnp.int32)[None]
        _, caches = model.prefill(tp, cfg, toks, max_len=N + 128)
        base = {}
        for gamma in sweep_gamma:
            variants = {
                "dense": None,  # handled below
                "nsa_norefresh": SSVConfig(refresh_schedule=(), group_mode="none"),
                "nsa_reuse": SSVConfig(refresh_schedule=reuse_sched,
                                       group_mode="none"),
                "nsa_reuse_exactC2": SSVConfig(refresh_schedule=reuse_sched,
                                               group_mode="exact", group_size=2),
                "nsa_reuse_approxC4": SSVConfig(refresh_schedule=reuse_sched,
                                                group_mode="approx", group_size=4),
            }
            t0 = None
            for name, ssv in variants.items():
                if name == "dense":
                    dcfg = dataclasses.replace(cfg, attention="dense",
                                               name=cfg.name + "-dense")
                    # dense verification over the same shapes (weights reuse the
                    # NSA projections; gates ignored)
                    continue
                t = bench_verify(tp, cfg, caches, gamma, ssv, csv,
                                 f"N{N}_g{gamma}_{name}")
                if name == "nsa_norefresh":
                    t0 = t
                elif t0:
                    csv.row(f"N{N}_g{gamma}_{name}_speedup", 0.0,
                            f"{t0 / t:.2f}x")
    return csv


if __name__ == "__main__":
    main()
