"""Fault-tolerance demo: training survives injected failures via
checkpoint/restart; elastic re-mesh planning on device loss.

  PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import shutil

from repro.config import ModelConfig, TrainConfig
from repro.runtime.elastic import plan_mesh
from repro.runtime.fault import FailureInjector, run_with_restarts
from repro.runtime.trainer import Trainer


def main():
    shutil.rmtree("/tmp/repro_fault_demo", ignore_errors=True)
    cfg = ModelConfig(name="fault-demo", num_layers=2, d_model=96,
                      num_heads=4, num_kv_heads=2, d_ff=192, vocab_size=256,
                      dtype="float32")
    tcfg = TrainConfig(steps=24, checkpoint_every=6, learning_rate=1e-3,
                       checkpoint_dir="/tmp/repro_fault_demo")
    injector = FailureInjector(fail_at_steps=[7, 15])  # two "preemptions"
    trainers = []

    def driver():
        tr = Trainer(cfg, tcfg, batch_size=4, seq_len=64, injector=injector)
        trainers.append(tr)
        print(f"  (re)started at step {tr.state.step}")
        return tr.run()

    report = run_with_restarts(driver)
    print(f"completed={report.completed} after {report.restarts} restarts, "
          f"final step {report.final_step}")
    final = trainers[-1]
    print(f"final loss {final.metrics_log[-1]['loss']:.3f}, "
          f"straggler events {len(final.watchdog.events)}")

    # elastic planning: what mesh would we rebuild on partial device loss?
    for n in (512, 384, 256, 128):
        mc = plan_mesh(n, prefer_model=16, multi_pod=(n > 256), pod_size=256)
        print(f"  {n} healthy chips -> mesh {mc.shape} axes {mc.axes}")


if __name__ == "__main__":
    main()
