"""Quickstart: build a tiny NSA target + draft, run one SSV
draft-verify-accept round by hand, then generate with the engine.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, NSAConfig, ServeConfig, SSVConfig
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.core.tree import build_topology, positions_for
from repro.models import model


def main():
    # 1. a small NSA target model and an even smaller draft
    cfg = ModelConfig(
        name="quickstart", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=512, max_seq_len=2048,
        dtype="float32", attention="nsa",
        nsa=NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4,
                      window=64))
    dcfg = draft_lib.draft_config(cfg, num_layers=1)
    key = jax.random.PRNGKey(0)
    target = model.init(key, cfg)
    draft = model.init(jax.random.fold_in(key, 1), dcfg)
    print(f"target: {cfg.param_count():,} params | draft: {dcfg.param_count():,}")

    # 2. one verification round, manually
    prompt = np.arange(32) % 512
    toks = jnp.asarray(prompt, jnp.int32)[None]
    _, caches = model.prefill(target, cfg, toks[:, :-1], max_len=256)
    topo = build_topology(depth=3, width=2, order="bfs")
    print(f"draft tree: {topo.num_nodes} nodes (incl. pending root), "
          f"depths {topo.depths.tolist()}")
    positions = jnp.asarray(positions_for(topo, 31))[None]
    tree_mask = jnp.asarray(topo.mask)[None]
    node_tokens = jnp.asarray(
        np.concatenate([[prompt[-1]], np.arange(topo.num_nodes - 1)]))[None]
    logits, _ = model.verify_step(target, cfg, caches, node_tokens, positions,
                                  tree_mask, jnp.asarray(topo.parents),
                                  SSVConfig(group_mode="exact", group_size=2,
                                            refresh_schedule=(1, 3)))
    print(f"verify logits: {logits.shape} (refresh layers 0,2; reuse 1,3)")

    # 3. full generation through the engine
    eng = engine_lib.SSVEngine(target, cfg, draft, dcfg, ServeConfig(
        max_new_tokens=24, temperature=0.0, max_context=256,
        ssv=SSVConfig(tree_depth=3, tree_width=2, group_size=2,
                      group_mode="exact", refresh_schedule=(1, 3),
                      precision_class="Reuse-only"),
        use_planner=False))
    res = eng.generate(prompt, max_new_tokens=24)
    print(f"generated {len(res.tokens)} tokens: {res.tokens[:12]}...")
    print(f"mean accepted drafts/step: {res.mean_accepted:.2f}, "
          f"throughput {res.accepted_token_throughput:.1f} tok/s")


if __name__ == "__main__":
    main()
