"""End-to-end driver: train a ~100M-class NSA target model for a few hundred
steps on the synthetic corpus with checkpoint/restart, then train a draft and
serve with SSV — the full paper pipeline at CPU scale.

Defaults are sized for CI (--full bumps to the 100M-class config):
  PYTHONPATH=src python examples/train_nsa_e2e.py --steps 200
"""
import argparse
import shutil

import numpy as np

from repro.config import ModelConfig, NSAConfig, ServeConfig, SSVConfig, TrainConfig
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.data.synthetic import SyntheticConfig, SyntheticCorpus
from repro.models import model
from repro.runtime.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config (slower on CPU)")
    ap.add_argument("--ckpt", default="/tmp/repro_e2e")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()
    if args.fresh:
        shutil.rmtree(args.ckpt, ignore_errors=True)

    if args.full:
        cfg = ModelConfig(name="nsa-100m", num_layers=8, d_model=768,
                          num_heads=12, num_kv_heads=4, d_ff=2048,
                          vocab_size=4096, max_seq_len=8192, dtype="float32",
                          attention="nsa",
                          nsa=NSAConfig(cmp_block=16, cmp_stride=8,
                                        sel_block=32, n_selected=8, window=128))
    else:
        cfg = ModelConfig(name="nsa-mini", num_layers=4, d_model=192,
                          num_heads=6, num_kv_heads=2, d_ff=384,
                          vocab_size=512, max_seq_len=4096, dtype="float32",
                          attention="nsa",
                          nsa=NSAConfig(cmp_block=8, cmp_stride=4,
                                        sel_block=16, n_selected=4, window=64))
    print(f"target {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")
    data = SyntheticConfig(vocab_size=cfg.vocab_size, seed=5)

    # ---- train target (resumes from checkpoint if present)
    tcfg = TrainConfig(steps=args.steps, learning_rate=3e-3, warmup_steps=20,
                       checkpoint_every=50, checkpoint_dir=args.ckpt + "/t")
    tr = Trainer(cfg, tcfg, data_cfg=data, batch_size=8, seq_len=256)
    tr.run()
    print(f"target trained to step {tr.state.step}: "
          f"loss {tr.metrics_log[-1]['loss']:.3f}" if tr.metrics_log else
          f"target resumed at final step {tr.state.step}")

    # ---- train draft
    dcfg = draft_lib.draft_config(cfg, num_layers=1)
    dtr = Trainer(dcfg, TrainConfig(steps=args.steps, learning_rate=3e-3,
                                    warmup_steps=20, checkpoint_every=50,
                                    checkpoint_dir=args.ckpt + "/d", seed=1),
                  data_cfg=data, batch_size=8, seq_len=256)
    dtr.run()

    # ---- serve with SSV, compare against autoregressive decode
    corpus = SyntheticCorpus(data)
    prompt = corpus.batch(999, 1, 64)[0]
    n = 48
    ar = engine_lib.autoregressive_decode(tr.state.params, cfg, prompt, n, 1024)
    eng = engine_lib.SSVEngine(
        tr.state.params, cfg, dtr.state.params, dcfg,
        ServeConfig(max_new_tokens=n, temperature=0.0, max_context=1024,
                    ssv=SSVConfig(tree_depth=4, tree_width=2, group_size=2,
                                  group_mode="exact",
                                  refresh_schedule=tuple(range(1, cfg.num_layers, 2)),
                                  precision_class="Reuse-only"),
                    use_planner=False))
    res = eng.generate(prompt, max_new_tokens=n)
    m = min(len(ar.tokens), len(res.tokens))
    agree = float((ar.tokens[:m] == res.tokens[:m]).mean())
    print(f"AR: {ar.accepted_token_throughput:.1f} tok/s | "
          f"SSV: {res.accepted_token_throughput:.1f} tok/s | "
          f"accepted/step {res.mean_accepted:.2f} | greedy agreement {agree:.0%}")


if __name__ == "__main__":
    main()
