"""Serve a stream of batched requests with SSV speculative decoding — the
serving-side end-to-end driver.

Default mode runs the device-resident `BatchedSSVEngine`: one vectorized
draft→verify→accept→commit launch per step advances every request, with
per-request committed lengths and completion masks. `--sequential` falls back
to looping single-stream `SSVEngine.generate` calls (the old path) so the
aggregate-throughput win of true batching is directly measurable.

`--continuous` switches the batched engine to continuous batching: requests
arrive over a Poisson-ish replay (`--arrival-rate` requests per fused step,
seeded by `--arrival-seed`) and are admitted into `--slots` batch slots as
rows free up — a per-slot re-prefill lands the new KV prefix in the donated
batch cache mid-flight, instead of draining the whole batch between waves.
The run reports per-request queue delay (virtual-step units), mean slot
occupancy, and aggregate throughput.

`--kv-backend paged` swaps the dense per-slot KV buffers for the paged
store (`repro.core.kvstore`): one physical page pool shared by every
request through per-row page tables, admission gated on free-page headroom,
pages freed on completion — KV memory scales with live tokens instead of
slots x max_context. `--kv-page-size` (default: the model's NSA sel_block,
making selected-block gather a page-table lookup) and `--kv-num-pages`
(pool capacity; 0 = worst case, no memory win) tune it. Token streams are
byte-identical to the dense backend (tests/test_engine_paged.py).

`--bucketed` (continuous mode) serves a mixed-length demo workload through
bucket-local execution groups: a `BatchPlanner` partitions the live slots
by context-regime bucket and each group runs one fused step under the
profile's strategy for that bucket, instead of the whole batch sharing one
tree topology. The scheduler admits bucket-homogeneously into freed slots.
`--warmup` AOT-compiles every reachable (strategy, group size) fused step
before serving, so mid-serve strategy switches never stall on a retrace.

  PYTHONPATH=src python examples/serve_batched.py --requests 4
  PYTHONPATH=src python examples/serve_batched.py --requests 4 --sequential
  PYTHONPATH=src python examples/serve_batched.py --requests 8 --continuous \\
      --slots 4 --arrival-rate 0.5
  PYTHONPATH=src python examples/serve_batched.py --requests 8 --continuous \\
      --slots 4 --kv-backend paged --kv-num-pages 48
  PYTHONPATH=src python examples/serve_batched.py --requests 8 --continuous \\
      --slots 4 --bucketed --warmup
"""
import argparse
import time

import jax
import numpy as np

from repro.config import ModelConfig, NSAConfig, ServeConfig, SSVConfig
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.core import planner as P
from repro.core import schedule as schedule_lib
from repro.data.synthetic import SyntheticConfig, SyntheticCorpus
from repro.models import model


def build_models():
    cfg = ModelConfig(name="serve-nsa", num_layers=4, d_model=128, num_heads=4,
                      num_kv_heads=2, d_ff=256, vocab_size=512,
                      max_seq_len=2048, dtype="float32", attention="nsa",
                      nsa=NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16,
                                    n_selected=4, window=64))
    dcfg = draft_lib.draft_config(cfg, num_layers=1)
    key = jax.random.PRNGKey(0)
    return model.init(key, cfg), cfg, model.init(jax.random.fold_in(key, 1), dcfg), dcfg


def build_profile(cfg, precision_class):
    """Tiny synthetic offline profile (normally produced by
    benchmarks/planner_eval.py-style calibration); CPU-sized trees."""
    mode, reuse = P.class_constraints(precision_class)
    sched = P.default_schedule(cfg.num_layers) if reuse else ()
    shapes = [(3, 2, "bfs"), (2, 2, "bfs"), (4, 2, "dfs"), (2, 4, "bfs")]
    entries = [P.ProfileEntry(
        SSVConfig(tree_depth=D, tree_width=k, traversal=t,
                  group_size=4 if mode == "approx" else 2, group_mode=mode,
                  refresh_schedule=sched, precision_class=precision_class),
        2.0 - 0.2 * i, 0.05) for i, (D, k, t) in enumerate(shapes)]
    return P.Profile(table={(b, pc): list(entries) for b in range(4)
                            for pc in P.PRECISION_CLASSES}), entries


def build_bucketed_profile(cfg, precision_class):
    """CPU-scale bucketed profile for the mixed-length demo: short-context
    requests get a shallow tree, long-context requests a deep one (per-
    bucket ranked lists, so the per-bucket runtime guards can refine)."""
    mode, reuse = P.class_constraints(precision_class)
    sched = P.default_schedule(cfg.num_layers) if reuse else ()
    C = 4 if mode == "approx" else 2
    mk = lambda D, k: SSVConfig(
        tree_depth=D, tree_width=k, traversal="bfs", group_size=C,
        group_mode=mode, refresh_schedule=sched,
        precision_class=precision_class)
    buckets = ((0, 64), (64, 256), (256, 1024), (1024, 4096))
    ranked = {0: [(1, 2), (2, 2)], 1: [(2, 2), (3, 2)],
              2: [(3, 2), (4, 2)], 3: [(4, 2), (4, 2)]}
    table = {(b, pc): [P.ProfileEntry(mk(D, k), 2.0 - 0.2 * i, 0.05)
                       for i, (D, k) in enumerate(ranked[b])]
             for b in range(len(buckets)) for pc in P.PRECISION_CLASSES}
    return P.Profile(table=table, buckets=buckets)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--precision-class", default="Reuse-only",
                    choices=list(P.PRECISION_CLASSES))
    ap.add_argument("--sequential", action="store_true",
                    help="loop single-stream SSVEngine instead of the batched engine")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: admit arrivals into freed "
                         "slots mid-flight instead of draining the batch")
    ap.add_argument("--slots", type=int, default=2,
                    help="batch slots for --continuous")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="Poisson arrival rate in requests per fused step "
                         "for --continuous (<=0: all arrive at t=0)")
    ap.add_argument("--arrival-seed", type=int, default=0,
                    help="seed for the Poisson arrival replay")
    ap.add_argument("--kv-backend", default="dense",
                    choices=("dense", "paged"),
                    help="KV store: dense per-slot buffers, or the paged "
                         "page-pool store (memory scales with live tokens)")
    ap.add_argument("--kv-page-size", type=int, default=0,
                    help="tokens per page (0 = model nsa.sel_block)")
    ap.add_argument("--kv-num-pages", type=int, default=0,
                    help="physical page-pool capacity (0 = worst case)")
    ap.add_argument("--bucketed", action="store_true",
                    help="continuous mode only: bucket-local execution "
                         "groups — each context-regime bucket of the batch "
                         "steps under its own profile strategy (serves a "
                         "mixed-length demo workload)")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile every reachable (strategy, group "
                         "size) fused step before serving (bucketed only)")
    args = ap.parse_args()
    if args.bucketed and not args.continuous:
        ap.error("--bucketed groups the continuous batch; add --continuous")
    if args.warmup and not args.bucketed:
        ap.error("--warmup pre-compiles the bucketed group-step cache; "
                 "add --bucketed")

    tp, cfg, dp, dcfg = build_models()
    profile, entries = build_profile(cfg, args.precision_class)
    corpus = SyntheticCorpus(SyntheticConfig(vocab_size=cfg.vocab_size))
    if args.bucketed:
        # mixed-length demo workload: alternate short- and long-context
        # prompts so the batch spans several profile buckets
        lengths = [24, 48, 96, 160]
        queue = [corpus.batch(i, 1, lengths[i % len(lengths)])[0]
                 for i in range(args.requests)]
    else:
        queue = [corpus.batch(i, 1, 48 + 16 * (i % 3))[0]
                 for i in range(args.requests)]
    serve_cfg = ServeConfig(max_new_tokens=args.tokens, temperature=0.0,
                            max_context=1024, ssv=entries[0].strategy,
                            use_planner=True,
                            kv_backend=args.kv_backend,
                            kv_page_size=args.kv_page_size,
                            kv_num_pages=args.kv_num_pages)

    t0 = time.time()
    if args.continuous:
        if args.bucketed:
            planner = P.BatchPlanner(build_bucketed_profile(
                cfg, args.precision_class), args.precision_class)
        else:
            planner = P.RuntimePlanner(profile, args.precision_class)
        eng = engine_lib.BatchedSSVEngine(tp, cfg, dp, dcfg, serve_cfg,
                                          planner=planner)
        arrivals = schedule_lib.poisson_arrivals(
            args.requests, args.arrival_rate, seed=args.arrival_seed)
        reqs = [schedule_lib.Request(req_id=i, prompt=queue[i],
                                     arrival=float(arrivals[i]))
                for i in range(args.requests)]
        res = eng.serve_continuous(reqs, num_slots=args.slots,
                                   max_new_tokens=args.tokens,
                                   warmup=args.warmup)
        total_tokens = res.total_tokens
        for req, gen in zip(res.requests, res.results):
            delay = (f"{req.queue_delay:.1f}" if req.queue_delay is not None
                     else "n/a (never admitted)")
            print(f"req {req.req_id}: ctx {len(req.prompt)} -> "
                  f"{len(gen.tokens)} tokens, arrival {req.arrival:.1f}, "
                  f"queue delay {delay} steps")
        print(f"continuous: {res.steps} fused steps over {args.slots} slots, "
              f"mean occupancy {res.mean_occupancy:.2f}, "
              f"mean queue delay {res.mean_queue_delay_steps:.1f} steps")
        if args.bucketed:
            occ = ", ".join(f"bucket{b}={v:.2f}"
                            for b, v in sorted(res.bucket_occupancy.items()))
            cache = res.kernel_cache
            print(f"bucketed: {res.group_launches} group launches "
                  f"({occ}); step cache "
                  f"{cache['step_cache_hits']} hits / "
                  f"{cache['step_cache_misses']} misses; kernel build cache "
                  f"{cache['verify_call_hits']} hits / "
                  f"{cache['verify_call_misses']} misses")
        if args.kv_backend == "paged":
            print(f"paged KV store: {res.kv_bytes} raw-KV bytes, page "
                  f"occupancy mean {res.mean_page_occupancy:.2f} / peak "
                  f"{res.peak_page_occupancy:.2f}")
    elif args.sequential:
        total_tokens = 0
        for i, prompt in enumerate(queue):
            planner = P.RuntimePlanner(profile, args.precision_class)
            eng = engine_lib.SSVEngine(tp, cfg, dp, dcfg, serve_cfg,
                                       planner=planner)
            res = eng.generate(prompt, max_new_tokens=args.tokens)
            total_tokens += len(res.tokens)
            strat = planner.current()
            print(f"req {i}: ctx {len(prompt)} -> {len(res.tokens)} tokens, "
                  f"{res.accepted_token_throughput:.1f} tok/s, "
                  f"strategy D{strat.tree_depth}k{strat.tree_width}/{strat.traversal}, "
                  f"refinements={planner.refinement_events}")
    else:
        # one planner for the whole batch: the strategy (hence tree topology)
        # is shared across rows so the step stays a single vectorized launch
        planner = P.RuntimePlanner(profile, args.precision_class)
        eng = engine_lib.BatchedSSVEngine(tp, cfg, dp, dcfg, serve_cfg,
                                          planner=planner)
        batch = eng.generate_batch(queue, max_new_tokens=args.tokens)
        total_tokens = batch.total_tokens
        strat = planner.current()
        for i, res in enumerate(batch.results):
            print(f"req {i}: ctx {len(queue[i])} -> {len(res.tokens)} tokens, "
                  f"mean accepted/step {res.mean_accepted:.2f}")
        print(f"batched: {batch.steps} fused steps, strategy "
              f"D{strat.tree_depth}k{strat.tree_width}/{strat.traversal}, "
              f"refinements={planner.refinement_events}")
    dt = time.time() - t0
    print(f"served {args.requests} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens / dt:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
