"""Serve a stream of batched requests with SSV speculative decoding + the
profile-guided planner — the serving-side end-to-end driver.

  PYTHONPATH=src python examples/serve_batched.py --requests 4
"""
import argparse
import time

import jax
import numpy as np

from repro.config import ModelConfig, NSAConfig, ServeConfig, SSVConfig
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.core import planner as P
from repro.data.synthetic import SyntheticConfig, SyntheticCorpus
from repro.models import model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--precision-class", default="Reuse-only",
                    choices=list(P.PRECISION_CLASSES))
    args = ap.parse_args()

    cfg = ModelConfig(name="serve-nsa", num_layers=4, d_model=128, num_heads=4,
                      num_kv_heads=2, d_ff=256, vocab_size=512,
                      max_seq_len=2048, dtype="float32", attention="nsa",
                      nsa=NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16,
                                    n_selected=4, window=64))
    dcfg = draft_lib.draft_config(cfg, num_layers=1)
    key = jax.random.PRNGKey(0)
    tp = model.init(key, cfg)
    dp = model.init(jax.random.fold_in(key, 1), dcfg)

    # offline profile: tiny synthetic one (normally produced by
    # benchmarks/planner_eval.py-style calibration); CPU-sized trees
    mode, reuse = P.class_constraints(args.precision_class)
    sched = P.default_schedule(cfg.num_layers) if reuse else ()
    shapes = [(3, 2, "bfs"), (2, 2, "bfs"), (4, 2, "dfs"), (2, 4, "bfs")]
    entries = [P.ProfileEntry(
        SSVConfig(tree_depth=D, tree_width=k, traversal=t,
                  group_size=4 if mode == "approx" else 2, group_mode=mode,
                  refresh_schedule=sched, precision_class=args.precision_class),
        2.0 - 0.2 * i, 0.05) for i, (D, k, t) in enumerate(shapes)]
    profile = P.Profile(table={(b, pc): list(entries) for b in range(4)
                               for pc in P.PRECISION_CLASSES})

    corpus = SyntheticCorpus(SyntheticConfig(vocab_size=cfg.vocab_size))
    queue = [corpus.batch(i, 1, 48 + 16 * (i % 3))[0]
             for i in range(args.requests)]

    total_tokens, t0 = 0, time.time()
    for i, prompt in enumerate(queue):
        planner = P.RuntimePlanner(profile, args.precision_class)
        eng = engine_lib.SSVEngine(tp, cfg, dp, dcfg, ServeConfig(
            max_new_tokens=args.tokens, temperature=0.0, max_context=1024,
            ssv=entries[0].strategy, use_planner=True), planner=planner)
        res = eng.generate(prompt, max_new_tokens=args.tokens)
        total_tokens += len(res.tokens)
        strat = planner.current()
        print(f"req {i}: ctx {len(prompt)} -> {len(res.tokens)} tokens, "
              f"{res.accepted_token_throughput:.1f} tok/s, "
              f"strategy D{strat.tree_depth}k{strat.tree_width}/{strat.traversal}, "
              f"refinements={planner.refinement_events}")
    dt = time.time() - t0
    print(f"served {args.requests} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens / dt:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
