"""Refresh/reuse schedule calibration + draft tree expansion + data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, NSAConfig
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.core.schedule import greedy_calibrate, kl_divergence
from repro.core.tree import build_topology
from repro.models import model


def test_kl_divergence_properties():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(4, 16))
    assert kl_divergence(a, a) < 1e-12
    b = rng.normal(size=(4, 16))
    assert kl_divergence(a, b) > 0


def test_greedy_calibrate_synthetic():
    """Layers have known per-layer KL costs; the calibrator must pick the
    cheap ones first and respect the budget."""
    rng = np.random.default_rng(0)
    base = rng.normal(size=(8, 32))
    cost = {1: 0.001, 2: 0.5, 3: 0.002, 4: 0.003, 5: 0.6, 6: 0.004, 7: 0.7}

    def eval_fn(schedule):
        out = base.copy()
        for l in schedule:
            noise = np.random.default_rng(l).normal(size=base.shape)
            out = out + cost[l] * noise
        return out

    sched = greedy_calibrate(eval_fn, num_layers=8, kl_budget=0.01)
    assert 0 not in sched                       # layer 0 never a candidate
    assert set(sched) <= {1, 3, 4, 6}           # only the cheap layers
    assert len(sched) >= 2


def test_greedy_calibrate_max_reuse():
    def eval_fn(schedule):
        return np.zeros((2, 8))                 # zero KL for everything
    sched = greedy_calibrate(eval_fn, num_layers=6, kl_budget=1.0, max_reuse=2)
    assert len(sched) == 2


@pytest.fixture(scope="module")
def tiny_pair():
    cfg = ModelConfig(name="d", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=64,
                      max_seq_len=256, dtype="float32", attention="nsa",
                      nsa=NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16,
                                    n_selected=4, window=32))
    dcfg = draft_lib.draft_config(cfg, num_layers=1)
    tp = model.init(jax.random.PRNGKey(0), cfg)
    dp = model.init(jax.random.PRNGKey(1), dcfg)
    return tp, cfg, dp, dcfg


def test_expand_tree_structure(tiny_pair):
    """Children tokens are the ranked top-k of the PARENT's draft logits,
    and node_q rows are valid distributions."""
    tp, cfg, dp, dcfg = tiny_pair
    toks = jnp.asarray(np.arange(24) % 64)[None]
    _, dcaches = model.prefill(dp, dcfg, toks[:, :-1], max_len=128)
    topo = build_topology(2, 2, "bfs")
    verify = engine_lib.jit_verify(dcfg, None)
    tokens, node_q, _ = draft_lib.expand_tree(
        lambda caches, tk, pos, tm, par: verify(dp, caches, tk, pos, tm, par),
        dcfg, dcaches, topo, jnp.asarray([int(toks[0, -1])], jnp.int32))
    tokens = np.asarray(tokens[0])
    q = np.asarray(node_q[0])
    assert tokens[0] == int(toks[0, -1])        # pending root preserved
    ranks = draft_lib.sibling_ranks(topo)
    for i in range(1, topo.num_nodes):
        p = int(topo.parents[i])
        topk = np.argsort(-q[p])[: ranks[i] + 1]
        assert tokens[i] == topk[ranks[i]]
    np.testing.assert_allclose(q.sum(-1), 1.0, rtol=1e-4)


def test_prefetch_iterator():
    from repro.data import PrefetchIterator, SyntheticConfig, SyntheticCorpus, token_stream
    c = SyntheticCorpus(SyntheticConfig(vocab_size=64))
    it = token_stream(c, batch_size=2, seq_len=16)
    pf = PrefetchIterator(it, depth=2)
    steps = []
    for _ in range(3):
        step, batch = next(pf)
        steps.append(step)
        assert batch.shape == (2, 16)
    assert steps == [0, 1, 2]
    pf.close()
