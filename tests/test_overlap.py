"""Property tests for the overlap machinery (merged schedule / shared index)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container images without hypothesis: skip, don't error
    pytest.skip("hypothesis not installed", allow_module_level=True)

from repro.core import overlap


@given(seed=st.integers(0, 500), B=st.integers(1, 2), T=st.integers(1, 9),
       H=st.integers(1, 3), n=st.integers(1, 6), C=st.integers(1, 4),
       nblocks=st.integers(4, 24))
@settings(max_examples=60, deadline=None)
def test_merged_schedule_is_union_with_ownership(seed, B, T, H, n, C, nblocks):
    rng = np.random.default_rng(seed)
    idx = np.sort(rng.integers(0, nblocks, (B, T, H, n)), axis=-1)
    val = rng.random((B, T, H, n)) < 0.8
    merged, own, mval = overlap.merged_schedule(jnp.asarray(idx),
                                                jnp.asarray(val), C)
    merged, own, mval = map(np.asarray, (merged, own, mval))
    qmap, pad = overlap.group_queries(T, C)
    G = qmap.shape[0]
    for b in range(B):
        for g in range(G):
            members = [q for k, q in enumerate(qmap[g]) if g * C + k < T]
            for h in range(H):
                want = set()
                for q in members:
                    want |= set(idx[b, q, h][val[b, q, h]].tolist())
                got = set(merged[b, g, h][mval[b, g, h]].tolist())
                assert got == want, (got, want)
                # sorted + deduped
                mv = merged[b, g, h][mval[b, g, h]]
                assert (np.diff(mv) > 0).all()
                # ownership: slot owned by query c iff block in c's set
                for k, q in enumerate(qmap[g]):
                    if g * C + k >= T:
                        continue
                    qset = set(idx[b, q, h][val[b, q, h]].tolist())
                    for s in range(merged.shape[-1]):
                        if mval[b, g, h, s]:
                            assert own[b, g, h, k, s] == (merged[b, g, h, s] in qset)


@given(seed=st.integers(0, 200), T=st.integers(1, 9), C=st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_shared_index_uses_deepest_member(seed, T, C):
    rng = np.random.default_rng(seed)
    B, H, n, nblocks = 1, 2, 3, 16
    idx = np.sort(rng.integers(0, nblocks, (B, T, H, n)), axis=-1)
    val = np.ones((B, T, H, n), bool)
    positions = np.arange(T)[None] + 100
    out_idx, out_val = overlap.shared_index(jnp.asarray(idx), jnp.asarray(val),
                                            jnp.asarray(positions), C)
    out_idx = np.asarray(out_idx)
    qmap, _ = overlap.group_queries(T, C)
    for g in range(qmap.shape[0]):
        members = [q for k, q in enumerate(qmap[g]) if g * C + k < T]
        rep = max(qmap[g])  # deepest = max position = max index here
        for q in members:
            assert (out_idx[0, q] == idx[0, rep]).all()


def test_overlap_ratio_bounds_and_symmetry(rng):
    idx_a = jnp.asarray(rng.integers(0, 10, (2, 4, 2, 4)))
    idx_b = jnp.asarray(rng.integers(0, 10, (2, 4, 2, 4)))
    va = jnp.ones((2, 4, 2, 4), bool)
    r_ab = np.asarray(overlap.overlap_ratio(idx_a, va, idx_b, va))
    r_ba = np.asarray(overlap.overlap_ratio(idx_b, va, idx_a, va))
    assert (r_ab >= 0).all() and (r_ab <= 1).all()
    assert np.allclose(r_ab, r_ba)
    r_aa = np.asarray(overlap.overlap_ratio(idx_a, va, idx_a, va))
    assert np.allclose(r_aa, 1.0)
