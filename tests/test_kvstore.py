"""KVCacheStore subsystem tests (repro.core.kvstore).

Property-style allocator invariants (hypothesis when installed, seeded
parametrized sweep otherwise — the PR-2/PR-3 shim pattern): no page
double-assignment, clean failure (state unchanged, queue keeps pending) on
exhaustion, everything freed on request completion, double-free rejected.

View-layer contracts: paged reads/writes resolve through the page table and
match the dense layout bit-for-bit; adversarial selected-block indices
(negative / out-of-range / unmapped) read an explicit zero page and are
masked out of NSA attention — never silently clamped onto a neighbor block
or another request's pages.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.config import ModelConfig, NSAConfig
from repro.core import kvstore as KS
from repro.core import schedule as S
from repro.models import nsa as nsa_lib


def seeded_property(n_examples=30, seed_max=10_000):
    if HAVE_HYPOTHESIS:
        def deco(fn):
            return settings(max_examples=n_examples, deadline=None)(
                given(seed=st.integers(0, seed_max))(fn))
        return deco

    def deco(fn):
        return pytest.mark.parametrize("seed", range(n_examples))(fn)
    return deco


# ------------------------------------------------------------------ allocator
@seeded_property()
def test_allocator_never_double_assigns(seed):
    """Across a random alloc/free interleave, live allocations are disjoint
    and every page id stays within the pool."""
    rng = np.random.default_rng(seed)
    total = int(rng.integers(4, 40))
    alloc = KS.PageAllocator(total)
    live = {}
    next_id = 0
    for _ in range(200):
        if rng.random() < 0.55:
            n = int(rng.integers(1, 6))
            pg = alloc.alloc(n)
            if pg is None:
                assert n > alloc.free_count     # only fails when short
                continue
            assert len(pg) == n
            flat = [p for ps in live.values() for p in ps]
            assert not set(pg.tolist()) & set(flat), "page double-assigned"
            assert all(0 <= p < total for p in pg.tolist())
            live[next_id] = pg.tolist()
            next_id += 1
        elif live:
            rid = list(live)[int(rng.integers(0, len(live)))]
            alloc.free(live.pop(rid))
        assert alloc.free_count + alloc.used_count == total
    for ps in live.values():
        alloc.free(ps)
    assert alloc.free_count == total and alloc.used_count == 0


@seeded_property(n_examples=15)
def test_allocator_exhaustion_is_clean(seed):
    """An alloc the pool cannot satisfy returns None and changes nothing —
    the caller's queue keeps the request pending."""
    rng = np.random.default_rng(seed)
    total = int(rng.integers(2, 10))
    alloc = KS.PageAllocator(total)
    held = alloc.alloc(total - 1)
    free_before = alloc.free_count
    assert alloc.alloc(2) is None
    assert alloc.free_count == free_before
    assert alloc.can_alloc(1) and not alloc.can_alloc(2)
    alloc.free(held)
    assert alloc.free_count == total


def test_allocator_rejects_double_free_and_foreign_pages():
    alloc = KS.PageAllocator(4)
    pg = alloc.alloc(2)
    alloc.free(pg)
    with pytest.raises(ValueError, match="not allocated"):
        alloc.free(pg)
    other = alloc.alloc(1)
    with pytest.raises(ValueError, match="not allocated"):
        alloc.free([3] if int(other[0]) != 3 else [2])
    with pytest.raises(ValueError):
        KS.PageAllocator(0)
    with pytest.raises(ValueError):
        alloc.alloc(0)


# ------------------------------------------------------------------ view layer
def _paged_twin(rng, B=2, S=64, H=2, D=8, ps=16, extra_pages=3, perm_seed=0):
    """A dense view and a paged view holding identical logical contents,
    with a shuffled physical page assignment (the realistic case)."""
    k = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    mp = S // ps
    P = B * mp + extra_pages
    order = np.random.default_rng(perm_seed).permutation(P)[: B * mp]
    pages = order.reshape(B, mp).astype(np.int32)
    poolk = jnp.zeros((P, ps, H, D), jnp.float32)
    poolv = jnp.zeros((P, ps, H, D), jnp.float32)
    for b in range(B):
        poolk = poolk.at[pages[b]].set(np.asarray(k[b]).reshape(mp, ps, H, D))
        poolv = poolv.at[pages[b]].set(np.asarray(v[b]).reshape(mp, ps, H, D))
    return (KS.KVView(k, v),
            KS.KVView(poolk, poolv, jnp.asarray(pages)))


@seeded_property(n_examples=10)
def test_view_read_paths_match_dense(seed):
    rng = np.random.default_rng(seed)
    dense, paged = _paged_twin(rng, perm_seed=seed)
    assert paged.is_paged and paged.max_len == dense.max_len
    np.testing.assert_array_equal(np.asarray(paged.full()[0]),
                                  np.asarray(dense.k))
    tok = jnp.asarray(rng.integers(-5, dense.max_len + 5, size=(2, 9)), jnp.int32)
    for a, b in zip(dense.gather_tokens(tok), paged.gather_tokens(tok)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # window lengths that do and do not divide the page size, at offsets
    # spanning the whole page (ws=15 with W%ps=8 is the regression case: a
    # one-page-short cover slid the window by a token)
    for W in (16, 24):
        for ws in (0, 3, 9, 15, 17, 31, 40):
            for a, b in zip(dense.window(jnp.int32(ws), W),
                            paged.window(jnp.int32(ws), W)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    idx = jnp.asarray(rng.integers(-3, 7, size=(2, 4, 2, 3)), jnp.int32)
    for a, b in zip(dense.gather_blocks(idx, 16), paged.gather_blocks(idx, 16)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_view_writes_match_dense_and_respect_masks(rng):
    dense, paged = _paged_twin(rng)
    kn = jnp.asarray(rng.normal(size=(2, 5, 2, 8)).astype(np.float32))
    vn = jnp.asarray(rng.normal(size=(2, 5, 2, 8)).astype(np.float32))
    dk, _ = dense.write(kn, vn, 10)
    pk, pv = paged.write(kn, vn, jnp.full((2,), 10), row_mask=jnp.array([True, True]))
    np.testing.assert_array_equal(
        np.asarray(KS.KVView(pk, pv, paged.pages).full()[0]), np.asarray(dk))
    # masked row writes are dropped — its pages (possibly re-owned by another
    # request by now) keep their bytes
    pk2, pv2 = paged.write(kn, vn, jnp.full((2,), 10),
                           row_mask=jnp.array([True, False]))
    after = np.asarray(KS.KVView(pk2, pv2, paged.pages).full()[0])
    np.testing.assert_array_equal(after[1], np.asarray(dense.k[1]))
    np.testing.assert_array_equal(after[0], np.asarray(dk[0]))
    # out-of-capacity positions are dropped, not clamped onto the last page
    before = np.asarray(paged.k)
    pk3, _ = paged.write(kn, vn, jnp.full((2,), paged.max_len - 2),
                         row_mask=jnp.array([True, True]))
    assert np.asarray(pk3).shape == before.shape   # no error, partial drop


# ------------------------------------------------ adversarial selected blocks
def test_gather_blocks_adversarial_indices_read_zero_pages(rng):
    """Out-of-range / negative / unmapped block indices must read an explicit
    zero page (regression: the seed clamped the gather onto block 0 / the
    last block, silently attending the wrong tokens)."""
    dense, paged = _paged_twin(rng)
    nsb = dense.max_len // 16
    bad = jnp.asarray([[[[-1, -7, nsb, nsb + 5]] * 2]], jnp.int32)
    bad = jnp.broadcast_to(bad, (2, 1, 2, 4))
    for view in (dense, paged):
        k_sel, v_sel = view.gather_blocks(bad, 16)
        np.testing.assert_array_equal(np.asarray(k_sel), 0.0)
        np.testing.assert_array_equal(np.asarray(v_sel), 0.0)
    # unmapped logical page (paged only): mapped region ends at max_len
    hole = jnp.concatenate([paged.pages, jnp.full((2, 2), -1, jnp.int32)], axis=1)
    holey = KS.KVView(paged.k, paged.v, hole)
    idx = jnp.full((2, 1, 2, 1), nsb, jnp.int32)   # first hole page
    k_sel, _ = holey.gather_blocks(idx, 16)
    np.testing.assert_array_equal(np.asarray(k_sel), 0.0)


def test_nsa_verify_ref_masks_adversarial_sel_idx(rng):
    """nsa_verify_ref with hostile sel_idx (negative + past-prefix, marked
    valid) must produce exactly the output of the same call with those slots
    marked invalid — adversarial indices can shift no attention mass."""
    NSA = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4,
                    window=32)
    cfg = ModelConfig(name="adv", num_layers=1, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=64,
                      dtype="float32", attention="nsa", nsa=NSA)
    params = nsa_lib.nsa_init(jax.random.PRNGKey(0), cfg)
    B, T, S, prefix = 1, 3, 128, 100
    x = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)).astype(np.float32))
    cache = {"k": jnp.asarray(rng.normal(size=(B, S, 2, 16)).astype(np.float32)),
             "v": jnp.asarray(rng.normal(size=(B, S, 2, 16)).astype(np.float32))}
    ncb = (S - NSA.cmp_block) // NSA.cmp_stride + 1
    cmp_cache = {"k_cmp": jnp.asarray(rng.normal(size=(B, ncb, 2, 16)).astype(np.float32)),
                 "v_cmp": jnp.asarray(rng.normal(size=(B, ncb, 2, 16)).astype(np.float32))}
    positions = jnp.asarray(prefix + np.arange(T))[None]
    tm = jnp.asarray(np.tril(np.ones((T, T), bool)))[None]
    good = jnp.asarray(np.sort(rng.integers(0, prefix // 16, (B, T, 2, 4)),
                               axis=-1), jnp.int32)
    valid = jnp.ones((B, T, 2, 4), bool)
    # slots 1 and 3 turn hostile: negative and far-out-of-range
    hostile = good.at[..., 1].set(-3).at[..., 3].set(S // 16 + 9)
    out_hostile = nsa_lib.nsa_verify_ref(params, cfg, x, cache, cmp_cache,
                                         prefix, positions, tm,
                                         sel_idx=hostile, sel_valid=valid,
                                         return_kv=False)
    out_masked = nsa_lib.nsa_verify_ref(params, cfg, x, cache, cmp_cache,
                                        prefix, positions, tm,
                                        sel_idx=hostile,
                                        sel_valid=valid.at[..., 1].set(False)
                                                       .at[..., 3].set(False),
                                        return_kv=False)
    np.testing.assert_array_equal(np.asarray(out_hostile),
                                  np.asarray(out_masked))


# ------------------------------------------------ scheduler page gating
def test_scheduler_page_gate_keeps_queue_pending_until_pages_free():
    """Admission requires free pages, not just a free slot: with the pool
    held, an arrived request stays queued (no exception, no placement); it
    admits as soon as pages free up. FIFO order survives the wait."""
    alloc = KS.PageAllocator(6)
    sched = S.Scheduler(2, pages_for=lambda r: 3,
                        free_pages=lambda: alloc.free_count, total_pages=6)
    hold = alloc.alloc(5)                      # 1 free < 3 needed
    sched.submit(S.Request(req_id=0, prompt=np.arange(4)))
    sched.submit(S.Request(req_id=1, prompt=np.arange(4)))
    assert sched.admit(0.0) == []              # gated, still pending
    assert len(sched.queue) == 2
    assert sched.page_occupancy() == pytest.approx(5 / 6)
    alloc.free(hold[:2])                       # 3 free now
    placed = sched.admit(1.0)
    assert [r.req_id for _, r in placed] == [0]
    alloc.alloc(3)                             # engine takes request 0's pages
    assert sched.admit(1.0) == []              # request 1 still gated
    alloc.free(hold[2:])
    placed = sched.admit(2.0)
    assert [r.req_id for _, r in placed] == [1]


def test_scheduler_page_gate_counts_same_call_reservations():
    """Two free slots, pages for only one request: a single admit() call must
    not place both (pages claimed by the first placement count against the
    second)."""
    alloc = KS.PageAllocator(4)
    sched = S.Scheduler(2, pages_for=lambda r: 3,
                        free_pages=lambda: alloc.free_count, total_pages=4)
    for i in range(2):
        sched.submit(S.Request(req_id=i, prompt=np.arange(4)))
    placed = sched.admit(0.0)
    assert [r.req_id for _, r in placed] == [0]


# ------------------------------------------------ config validation
def test_store_config_validation():
    nsa_cfg = ModelConfig(name="v", num_layers=1, d_model=32, num_heads=2,
                          num_kv_heads=2, d_ff=64, vocab_size=32,
                          attention="nsa",
                          nsa=NSAConfig(cmp_block=8, cmp_stride=4,
                                        sel_block=16, n_selected=4, window=32))
    with pytest.raises(ValueError, match="backend"):
        KS.KVStoreConfig(backend="ragged")
    with pytest.raises(ValueError, match="sel_block"):
        KS.KVStoreConfig("paged", page_size=24).resolved_page_size(nsa_cfg)
    st_cfg = KS.KVStoreConfig("paged")
    assert st_cfg.resolved_page_size(nsa_cfg) == 16
    with pytest.raises(ValueError, match="multiple"):
        st_cfg.logical_pages(100, 16)
    assert st_cfg.logical_pages(256, 16) == 16
    assert KS.pages_needed(0, 16) == 1 and KS.pages_needed(17, 16) == 2
