"""Device-side accept parity: the pure-jnp greedy/stochastic tree-accept
walks (fused into the jitted serving step) must produce exactly the same
paths, tokens, and bonus as the host numpy implementations, across
randomized tree topologies. Also re-asserts distribution exactness of the
uniform-driven stochastic rule (no hypothesis dependency)."""
import numpy as np
import pytest

from repro.core import accept as accept_lib
from repro.core.tree import build_topology, chain_topology, children_matrix


def _random_case(seed):
    rng = np.random.default_rng(seed)
    depth = int(rng.integers(1, 5))
    width = int(rng.integers(1, 4))
    order = ["bfs", "dfs"][int(rng.integers(0, 2))]
    budget = int(rng.integers(0, 2)) * int(rng.integers(3, 12))
    topo = build_topology(depth, width, order, budget)
    V = int(rng.integers(5, 20))
    # small vocab on purpose: sibling-duplicate tokens exercise the
    # first-matching-child tie-break the device walk must reproduce
    tokens = rng.integers(0, V, topo.num_nodes)
    logits = rng.normal(size=(topo.num_nodes, V)).astype(np.float32)
    q = rng.dirichlet(np.ones(V), size=topo.num_nodes).astype(np.float32)
    return rng, topo, tokens, logits, q


@pytest.mark.parametrize("block", range(4))
def test_greedy_device_matches_host(block):
    for seed in range(block * 40, block * 40 + 40):
        rng, topo, tokens, logits, _ = _random_case(seed)
        cm = children_matrix(topo)
        maxd = int(topo.depths.max())
        host = accept_lib.greedy_tree_accept(topo, tokens, logits)
        path, toks, bonus, n_acc = accept_lib.greedy_tree_accept_device(
            cm, maxd, tokens, logits)
        n = int(n_acc)
        assert n == host.n_accepted, seed
        assert np.array_equal(np.asarray(path)[: n + 1], host.path), seed
        assert np.array_equal(np.asarray(toks)[: n + 1], host.tokens), seed
        assert int(bonus) == host.bonus, seed
        # padding repeats the last path entry (the jitted-commit layout)
        assert np.all(np.asarray(path)[n:] == host.path[-1]), seed


@pytest.mark.parametrize("block", range(4))
def test_stochastic_device_matches_host(block):
    for seed in range(block * 40, block * 40 + 40):
        rng, topo, tokens, logits, q = _random_case(seed)
        cm = children_matrix(topo)
        maxd = int(topo.depths.max())
        accept_u, bonus_u = accept_lib.draw_uniforms(topo, rng)
        temp = 0.5 + 0.5 * float(rng.uniform())
        host = accept_lib.stochastic_tree_accept_uniforms(
            topo, tokens, logits, q, accept_u, bonus_u, temp)
        path, toks, bonus, n_acc = accept_lib.stochastic_tree_accept_device(
            cm, maxd, tokens, logits, q, accept_u.astype(np.float32),
            np.float32(bonus_u), temp)
        n = int(n_acc)
        assert n == host.n_accepted, seed
        assert np.array_equal(np.asarray(path)[: n + 1], host.path), seed
        assert np.array_equal(np.asarray(toks)[: n + 1], host.tokens), seed
        assert int(bonus) == host.bonus, seed


def test_stochastic_rng_entrypoint_matches_uniform_form():
    """The rng-drawing wrapper must be a pure re-parameterization of the
    uniform-driven core."""
    rng, topo, tokens, logits, q = _random_case(3)
    r1 = accept_lib.stochastic_tree_accept(topo, tokens, logits, q,
                                           np.random.default_rng(11), 1.0)
    au, bu = accept_lib.draw_uniforms(topo, np.random.default_rng(11))
    r2 = accept_lib.stochastic_tree_accept_uniforms(topo, tokens, logits, q,
                                                    au, bu, 1.0)
    assert np.array_equal(r1.path, r2.path)
    assert np.array_equal(r1.tokens, r2.tokens)


def test_stochastic_preserves_target_distribution():
    """With gamma=1, the emitted first token must be distributed exactly as
    the target softmax regardless of the draft distribution q (the SpecInfer
    exactness invariant — kept here free of the hypothesis dependency)."""
    rng = np.random.default_rng(0)
    V = 5
    topo = chain_topology(1)
    t_logits = np.array([0.0, 1.0, 2.0, -1.0, 0.5], np.float32)
    p = np.exp(t_logits - t_logits.max())
    p /= p.sum()
    q = np.array([0.5, 0.1, 0.1, 0.2, 0.1], np.float32)
    counts = np.zeros(V)
    N = 4000
    for _ in range(N):
        tok = rng.choice(V, p=q / q.sum())
        tokens = np.array([0, tok])
        logits = np.stack([t_logits, t_logits])
        node_q = np.stack([q, q])
        res = accept_lib.stochastic_tree_accept(topo, tokens, logits, node_q,
                                                rng, temperature=1.0)
        counts[res.tokens[0]] += 1
    emp = counts / N
    assert np.abs(emp - p).max() < 0.05, (emp, p)


def test_children_matrix_layout():
    topo = build_topology(2, 2, "bfs")
    cm = children_matrix(topo)
    assert cm.shape == (topo.num_nodes, 2)
    assert cm[0].tolist() == [1, 2]      # root's children in sibling order
    assert cm[3].tolist() == [-1, -1]    # leaves are -1 padded
