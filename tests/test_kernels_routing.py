"""Routing kernel (fused cmp attention + selection scores) vs oracle, and
vs the model-level nsa.routing reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import NSAConfig
from repro.kernels.routing import ops as rops, ref as rref
from repro.models.nsa import num_cmp_blocks, num_sel_blocks, overlap_matrix

NSA = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4, window=32)


@pytest.mark.parametrize("B,T,Hq,Hkv,Dh,S,prefix", [
    (1, 4, 2, 1, 16, 96, 80),
    (2, 6, 4, 2, 32, 128, 100),
    (1, 8, 8, 4, 64, 160, 33),
])
def test_routing_matches_oracle(B, T, Hq, Hkv, Dh, S, prefix):
    rng = np.random.default_rng(B + T)

    def r(*shape):
        return jnp.asarray(rng.normal(size=shape), jnp.float32)
    NCB = num_cmp_blocks(S, NSA)
    NSB = num_sel_blocks(S, NSA)
    ncb_valid = num_cmp_blocks(prefix, NSA)
    q = r(B, T, Hq, Dh) / np.sqrt(Dh)
    kc, vc = r(B, NCB, Hkv, Dh), r(B, NCB, Hkv, Dh)
    positions = jnp.asarray(prefix + np.minimum(np.arange(T), 3))[None].repeat(B, 0)

    o_k, p_k = rops.routing_fused(q, kc, vc, positions, ncb_valid, NSA, kv_len=S)
    M = jnp.asarray(overlap_matrix(NCB, NSB, NSA.cmp_block, NSA.cmp_stride,
                                   NSA.sel_block))
    o_r, p_r = rref.ref_routing(q, kc, vc, M, positions, ncb_valid,
                                cmp_block=NSA.cmp_block, cmp_stride=NSA.cmp_stride)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), rtol=2e-4,
                               atol=2e-5)
    # p_slc: both kernel and oracle return GQA-group-summed (B,T,Hkv,NSB)
    np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_r), rtol=2e-4,
                               atol=2e-5)


def test_routing_matches_model_reference():
    from repro.config import ModelConfig
    from repro.models import model, nsa as nsa_lib
    from repro.models.attention import qkv
    cfg = ModelConfig(name="t", num_layers=1, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=97, dtype="float32",
                      attention="nsa", nsa=NSA)
    key = jax.random.PRNGKey(0)
    p = model.init(key, cfg)
    bp = jax.tree.map(lambda a: a[0], p["segments"][0][0])
    toks = jax.random.randint(key, (1, 100), 0, 97)
    _, caches = model.prefill(p, cfg, toks, max_len=160)
    cache = jax.tree.map(lambda a: a[0], caches["segments"][0][0])
    T = 5
    x = jax.random.normal(key, (1, T, 64))
    positions = jnp.asarray(100 + np.minimum(np.arange(T), 2))[None]
    q, _, _ = qkv(bp["mix"], cfg, x, positions)
    ncb_valid = nsa_lib.num_cmp_blocks(100, NSA)
    o_ref, p_ref = nsa_lib.routing(bp["mix"], cfg, q, cache["cmp"]["k_cmp"],
                                   cache["cmp"]["v_cmp"], positions,
                                   kv_len=160, ncb_valid=ncb_valid)
    o_k, p_k = rops.routing_fused(q / np.sqrt(cfg.head_dim),
                                  cache["cmp"]["k_cmp"], cache["cmp"]["v_cmp"],
                                  positions, ncb_valid, NSA, kv_len=160)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref, np.float32),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_ref, np.float32),
                               rtol=2e-4, atol=2e-5)
