import numpy as np
import pytest

# NOTE: no XLA_FLAGS device override here — smoke tests and benches must see
# exactly 1 device. Multi-device behavior is tested via subprocesses
# (tests/test_distributed.py) which set the flag before importing jax.


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run tests marked slow (long randomized stress "
                          "runs that are opt-in, not tier-1)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-horizon / many-seed stress test, opt-in via "
                   "--runslow (a seeded small case of the same invariant "
                   "stays in tier-1)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
