import numpy as np
import pytest

# NOTE: no XLA_FLAGS device override here — smoke tests and benches must see
# exactly 1 device. Multi-device behavior is tested via subprocesses
# (tests/test_distributed.py) which set the flag before importing jax.


@pytest.fixture
def rng():
    return np.random.default_rng(0)
