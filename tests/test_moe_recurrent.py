"""MoE dispatch correctness + recurrent-cell equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig, RecurrentConfig
from repro.models import moe as moe_lib
from repro.models import recurrent as rec


def _moe_cfg(E=4, k=2, cf=10.0):
    return ModelConfig(name="m", num_layers=1, d_model=32, num_heads=2,
                       num_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
                       block_pattern=("moe",),
                       moe=MoEConfig(num_experts=E, top_k=k, d_expert=48,
                                     capacity_factor=cf, dispatch_group=16))


def _ref_moe(params, cfg, x):
    """Naive per-token loop oracle (no capacity limit)."""
    B, S, d = x.shape
    xf = np.asarray(x.reshape(B * S, d))
    probs, topk_idx, topk_w = moe_lib.router_probs(params, jnp.asarray(xf), cfg.moe)
    probs, topk_idx, topk_w = map(np.asarray, (probs, topk_idx, topk_w))
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(cfg.moe.top_k):
            e = topk_idx[t, j]
            xe = jnp.asarray(xf[t:t + 1])
            h = jax.nn.silu(xe @ params["w_gate"][e]) * (xe @ params["w_up"][e])
            y = np.asarray(h @ params["w_down"][e])[0]
            out[t] += topk_w[t, j] * y
    return out.reshape(B, S, d)


def test_moe_matches_per_token_oracle():
    cfg = _moe_cfg(cf=10.0)  # capacity never binds
    key = jax.random.PRNGKey(0)
    params = moe_lib.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 32))
    got, aux = moe_lib.moe_apply(params, cfg, x)
    want = _ref_moe(params, cfg, x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(cf=0.25)  # tight capacity
    key = jax.random.PRNGKey(0)
    params = moe_lib.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 32, 32))
    got, _ = moe_lib.moe_apply(params, cfg, x)
    want = _ref_moe(params, cfg, x)
    # some tokens dropped => outputs differ, but must stay finite and smaller
    # or equal in magnitude (dropped tokens contribute zero)
    assert np.isfinite(np.asarray(got)).all()
    assert float(jnp.abs(got).sum()) <= float(np.abs(want).sum()) + 1e-3


def test_moe_load_balance_loss_range():
    probs = jnp.asarray(np.random.default_rng(0).dirichlet(np.ones(4), size=64),
                        jnp.float32)
    idx = jnp.asarray(np.asarray(probs).argsort(-1)[:, -2:])
    l = float(moe_lib.load_balance_loss(probs, idx, 4))
    assert 0.5 < l < 4.0  # E * sum f*p ~ 1 when balanced


CFG_R = ModelConfig(name="r", num_layers=1, d_model=32, num_heads=4,
                    num_kv_heads=4, d_ff=0, vocab_size=64, dtype="float32",
                    recurrent=RecurrentConfig(kind="rglru", num_heads=4))


def test_rglru_scan_equals_steps():
    key = jax.random.PRNGKey(0)
    p = rec.rglru_init(key, CFG_R)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 12, 32))
    out_train = rec.rglru_apply_train(p, CFG_R, x)
    state = rec.rglru_init_state(CFG_R, 2)
    outs = []
    for t in range(12):
        o, state = rec.rglru_step(p, CFG_R, x[:, t:t + 1], state)
        outs.append(np.asarray(o[:, 0]))
    np.testing.assert_allclose(np.asarray(out_train), np.stack(outs, 1),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("kind", ["mlstm", "slstm"])
def test_xlstm_scan_equals_steps(kind):
    key = jax.random.PRNGKey(0)
    p = rec.INITS[kind](key, CFG_R)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 10, 32))
    out_train = rec.TRAIN[kind](p, CFG_R, x)
    state = rec.STATE_INITS[kind](CFG_R, 2)
    outs = []
    for t in range(10):
        o, state = rec.STEPS[kind](p, CFG_R, x[:, t:t + 1], state)
        outs.append(np.asarray(o[:, 0]))
    np.testing.assert_allclose(np.asarray(out_train), np.stack(outs, 1),
                               rtol=1e-4, atol=1e-5)


def test_verify_states_chain_equals_sequential():
    """State replay over a chain tree == stepping sequentially."""
    key = jax.random.PRNGKey(0)
    p = rec.mlstm_init(key, CFG_R)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 5, 32))
    state = rec.mlstm_init_state(CFG_R, 1)
    parents = jnp.asarray([-1, 0, 1, 2, 3])
    outs, buf = rec.verify_states(rec.mlstm_step, p, CFG_R, x, parents, state)
    st = state
    seq = []
    for t in range(5):
        o, st = rec.mlstm_step(p, CFG_R, x[:, t:t + 1], st)
        seq.append(np.asarray(o[:, 0]))
    np.testing.assert_allclose(np.asarray(outs[0]), np.stack(seq, 0)[:, 0],
                               rtol=1e-4, atol=1e-5)


def test_verify_states_branching():
    """Two children of the same parent must each start from the PARENT state,
    not from each other's."""
    key = jax.random.PRNGKey(0)
    p = rec.slstm_init(key, CFG_R)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 3, 32))
    state = rec.slstm_init_state(CFG_R, 1)
    # tree: root(0) -> {1, 2}, same token inputs at nodes 1 and 2
    x_same = x.at[:, 2].set(x[:, 1])
    parents = jnp.asarray([-1, 0, 0])
    outs, _ = rec.verify_states(rec.slstm_step, p, CFG_R, x_same, parents, state)
    np.testing.assert_allclose(np.asarray(outs[0, 1]), np.asarray(outs[0, 2]),
                               rtol=1e-5, atol=1e-6)
