"""Substrate tests: data determinism, checkpoint roundtrip/atomicity,
compression fidelity, straggler watchdog, optimizer."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import AsyncCheckpointer, latest_step, restore, save
from repro.config import TrainConfig
from repro.data.synthetic import SyntheticConfig, SyntheticCorpus
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, compress
from repro.runtime.straggler import StragglerWatchdog


def test_data_deterministic_and_sharded():
    c = SyntheticCorpus(SyntheticConfig(vocab_size=128, seed=7))
    a = c.batch(5, 8, 32)
    b = c.batch(5, 8, 32)
    assert (a == b).all()  # restart-reproducible
    assert not (c.batch(6, 8, 32) == a).all()
    # shards partition the global batch
    full = c.batch(3, 8, 32)
    sh0 = c.batch(3, 8, 32, shard=0, num_shards=2)
    sh1 = c.batch(3, 8, 32, shard=1, num_shards=2)
    assert (np.concatenate([sh0, sh1]) == full).all()


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "t": (jnp.zeros((2,)), jnp.int32(3))}
    d = str(tmp_path / "ck")
    save(d, 7, tree, metadata={"x": 1})
    step, got = restore(d, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomic_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.ones((3,))}
    for s in (1, 2, 3, 4, 5):
        save(d, s, tree)
    from repro.ckpt import gc_old
    gc_old(d, keep=2)
    assert latest_step(d) == 5
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
    assert steps == [4, 5]
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    ck = AsyncCheckpointer(d)
    ck.save(3, {"w": jnp.full((8,), 3.0)})
    ck.wait()
    step, got = restore(d, {"w": jnp.zeros((8,))})
    assert step == 3 and float(got["w"][0]) == 3.0


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path / "ck")
    save(d, 1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        restore(d, {"w": jnp.ones((5,))})


def test_compression_roundtrip_and_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    res = compress.init_residual(g)
    quant, res2 = compress.compress_pytree(g, res, jnp.int32(0))
    deq = compress.decompress_pytree(quant)
    err = float(jnp.abs(deq["w"] - g["w"]).max())
    scale = float(jnp.abs(g["w"]).max())
    assert err <= scale / 127.0 * 1.01  # one quantization bin
    # error feedback carries the residual
    assert float(jnp.abs(res2["w"]).max()) > 0
    np.testing.assert_allclose(np.asarray(deq["w"] + res2["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)


def test_adamw_descends_quadratic():
    tcfg = TrainConfig(steps=200, learning_rate=0.1, warmup_steps=1,
                       weight_decay=0.0)
    p = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        g, _ = clip_by_global_norm(g, 100.0)
        p, opt = adamw_update(g, opt, p, tcfg)
    assert float(jnp.abs(p["w"]).max()) < 0.3


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=2.0, warmup_steps=2)
    for i in range(8):
        wd.observe(i, 0.1)
    ev = wd.observe(8, 0.5)   # 5x the EMA
    assert ev is not None and ev.ratio > 2.0
    assert len(wd.events) == 1
    # EMA not poisoned by the straggler
    assert wd.ema < 0.12
