"""Shape/dtype sweep of the flash tree-verification kernel vs its oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash import ops as fops, ref as fref


def run(B, T, Hq, Hkv, Dh, S, prefix, window, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)

    def r(*shape):
        return jnp.asarray(rng.normal(size=shape), dtype)
    q = r(B, T, Hq, Dh) / np.sqrt(Dh)
    kc, vc = r(B, S, Hkv, Dh), r(B, S, Hkv, Dh)
    kd, vd = r(B, T, Hkv, Dh), r(B, T, Hkv, Dh)
    depths = np.minimum(np.arange(T), 3)
    positions = jnp.asarray(prefix + depths)[None].repeat(B, 0)
    tm = jnp.asarray(np.tril(np.ones((T, T), bool)))[None].repeat(B, 0)
    out_k = fops.flash_verify(q, kc, vc, kd, vd, positions, prefix, tm, window)
    out_r = fref.ref_flash_verify(q, kc, vc, kd, vd, positions, prefix, tm, window)
    return np.asarray(out_k, np.float32), np.asarray(out_r, np.float32)


@pytest.mark.parametrize("B,T,Hq,Hkv,Dh,S,prefix,window", [
    (1, 4, 2, 1, 16, 64, 48, 0),
    (2, 6, 4, 2, 32, 96, 80, 0),
    (1, 5, 6, 3, 16, 64, 50, 24),
    (2, 8, 8, 8, 64, 160, 130, 0),
    (1, 7, 4, 4, 32, 144, 10, 16),   # tiny prefix
])
def test_flash_matches_oracle(B, T, Hq, Hkv, Dh, S, prefix, window):
    out_k, out_r = run(B, T, Hq, Hkv, Dh, S, prefix, window)
    np.testing.assert_allclose(out_k, out_r, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.bfloat16, 3e-2)])
def test_flash_bf16(dtype, tol):
    out_k, out_r = run(1, 4, 4, 2, 32, 96, 80, 0, dtype=dtype)
    np.testing.assert_allclose(out_k, out_r, rtol=tol, atol=tol)
