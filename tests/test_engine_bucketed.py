"""Bucket-local batched planning invariants.

The core invariant mirrors test_engine_continuous.py one level up: every
request served by grouped ``serve_continuous`` — live slots partitioned into
context-regime execution groups, each group stepping under its bucket's
profile strategy, mid-flight admission, dense and paged KV backends — is
byte-identical to single-stream ``SSVEngine.generate`` under that row's
bucket strategy. On top sit the AOT warmup contract (no group-step compiles
mid-serve once warmed), the group-step isolation guarantee (rows outside a
group keep every cache byte), and the kernel-cache metrics satellites.
"""
import jax
import numpy as np
import pytest

from repro.config import ModelConfig, NSAConfig, ServeConfig, SSVConfig
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.core import overlap
from repro.core import planner as P
from repro.core import schedule as schedule_lib
from repro.models import model

NSA = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4, window=32)
MAX_NEW = 8
BUCKETS = ((0, 20), (20, 512))
SHORT = SSVConfig(tree_depth=1, tree_width=2)
LONG = SSVConfig(tree_depth=2, tree_width=2)

# lengths 18/15/17 fall in bucket 0, 23/20/21 in bucket 1
PROMPTS = [np.arange(18) % 64, (np.arange(23) * 3) % 64,
           (np.arange(15) * 7) % 64, (np.arange(20) * 5) % 64,
           (np.arange(17) * 11) % 64, (np.arange(21) * 13) % 64]


def _strategy_of(prompt) -> SSVConfig:
    return (SHORT, LONG)[P.bucket_of(len(prompt), BUCKETS)]


def _profile():
    # expected_accept 0.0 keeps the per-bucket runtime guards quiescent, so
    # each bucket's strategy — and therefore its token streams — is fixed
    table = {(0, "Strict"): [P.ProfileEntry(SHORT, 0.0, 0.01)],
             (1, "Strict"): [P.ProfileEntry(LONG, 0.0, 0.01)]}
    return P.Profile(table=table, buckets=BUCKETS)


def _serve(backend="dense", ssv=LONG, n=MAX_NEW):
    return ServeConfig(max_new_tokens=n, temperature=0.0, max_context=256,
                       ssv=ssv, use_planner=False, kv_backend=backend)


@pytest.fixture(scope="module")
def bk_pair():
    tcfg = ModelConfig(name="bkgt", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=64,
                       max_seq_len=512, dtype="float32", attention="nsa",
                       nsa=NSA)
    dcfg = draft_lib.draft_config(tcfg, num_layers=1)
    tp = model.init(jax.random.PRNGKey(0), tcfg)
    dp = model.init(jax.random.PRNGKey(1), dcfg)
    return tp, tcfg, dp, dcfg


@pytest.fixture(scope="module")
def bucket_reference(bk_pair):
    """Greedy single-stream output per prompt UNDER ITS BUCKET STRATEGY —
    the ground truth bucket-local serving must reproduce exactly."""
    tp, tcfg, dp, dcfg = bk_pair
    ref = []
    for p in PROMPTS:
        eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg,
                                   _serve(ssv=_strategy_of(p)))
        ref.append(eng.generate(p, max_new_tokens=MAX_NEW).tokens)
    return ref


def _random_requests(seed, max_arrival=6):
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(PROMPTS))
    return [schedule_lib.Request(req_id=int(i), prompt=PROMPTS[int(i)],
                                 arrival=float(rng.integers(0, max_arrival)))
            for i in order]


@pytest.mark.parametrize("slots,backend", [(1, "dense"), (2, "dense"),
                                           (3, "paged"), (4, "paged")])
def test_bucketed_token_equality(bk_pair, bucket_reference, slots, backend):
    """Byte-identical tokens for every request under grouped serving: mixed
    prompt lengths spanning both buckets, random arrival orders (mid-flight
    admission), every slot count, both KV backends."""
    tp, tcfg, dp, dcfg = bk_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve(backend),
                                      planner=P.BatchPlanner(_profile(),
                                                             "Strict"))
    reqs = _random_requests(seed=slots)
    res = eng.serve_continuous(reqs, num_slots=slots, max_new_tokens=MAX_NEW)
    assert len(res.results) == len(PROMPTS)
    for req, gen in zip(res.requests, res.results):
        np.testing.assert_array_equal(
            bucket_reference[req.req_id], gen.tokens,
            err_msg=f"request {req.req_id} diverged from single-stream under "
                    f"its bucket strategy (slots={slots}, backend={backend})")
    # the run really exercised mid-flight admission and bucket grouping
    if slots < len(PROMPTS):
        assert max(r.admitted_at for r in res.requests) > 0.0
    assert all(r.finished_at is not None for r in res.requests)
    assert res.group_launches >= res.steps
    assert set(res.bucket_occupancy) == {0, 1}
    assert all(0.0 < v <= 1.0 for v in res.bucket_occupancy.values())
    # engine metrics carry the cache counters next to kv_cache_bytes
    for key in ("step_cache_hits", "step_cache_misses", "verify_call_hits",
                "verify_call_misses", "group_layout_hits",
                "group_layout_misses"):
        assert key in res.kernel_cache


def test_warmup_precompiles_every_reachable_step(bk_pair):
    """``warmup`` AOT-compiles (strategy x padded group size) up front; the
    serve loop then never compiles — every launch is a step-cache hit."""
    tp, tcfg, dp, dcfg = bk_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve(),
                                      planner=P.BatchPlanner(_profile(),
                                                             "Strict"))
    n = eng.warmup(num_slots=2)
    assert n == 4                      # {SHORT, LONG} x group sizes {1, 2}
    assert eng.step_cache.misses == n
    res = eng.serve_continuous(_random_requests(seed=7), num_slots=2,
                               max_new_tokens=MAX_NEW)
    assert eng.step_cache.misses == n, "a group step compiled mid-serve"
    assert eng.step_cache.hits >= res.group_launches
    # warming again is free: everything already cached
    assert eng.warmup(num_slots=2) == 0


def test_bucketed_serving_requires_batch_planner(bk_pair):
    tp, tcfg, dp, dcfg = bk_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve())
    with pytest.raises(ValueError, match="BatchPlanner"):
        eng.serve_continuous([PROMPTS[0]], num_slots=2, bucketed=True)
    with pytest.raises(ValueError, match="warmup"):
        eng.serve_continuous([PROMPTS[0]], num_slots=2, warmup=True)
    with pytest.raises(ValueError, match="BatchPlanner"):
        eng.warmup(num_slots=2)
    bp = P.BatchPlanner(_profile(), "Strict")
    beng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve(),
                                       planner=bp)
    with pytest.raises(ValueError, match="bucketed"):
        beng.serve_continuous([PROMPTS[0]], num_slots=2, bucketed=False)
    with pytest.raises(ValueError, match="BatchedSSVEngine"):
        engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve(), planner=bp)
    # the drain-entry API stays usable under a BatchPlanner: start() resets
    # the per-bucket guards, step() demands an explicit strategy (there is
    # no single batch-wide plan to fall back to)
    beng.start([PROMPTS[0], PROMPTS[2]])
    with pytest.raises(ValueError, match="strategy"):
        beng.step(active=np.array([True, True]))
    toks, n_acc = beng.step(active=np.array([True, True]), strategy=SHORT)
    assert toks.shape[0] == 2 and n_acc.shape == (2,)


def test_step_group_validates_rows(bk_pair):
    tp, tcfg, dp, dcfg = bk_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve())
    eng.start_empty(2)
    with pytest.raises(ValueError, match="empty"):
        eng.step_group([], SHORT)
    with pytest.raises(ValueError, match="duplicate"):
        eng.step_group([0, 0], SHORT)
    with pytest.raises(ValueError, match="range"):
        eng.step_group([2], SHORT)


def test_step_group_leaves_other_rows_untouched(bk_pair):
    """Group-step isolation: stepping rows {0, 1} under one strategy must
    not change a single byte of row 2's KV, its device length, its pending
    admission reset, or its host mirrors."""
    tp, tcfg, dp, dcfg = bk_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve())
    eng.start_empty(3)
    for slot in range(3):
        eng.admit(slot, PROMPTS[slot])
    row2_before = [np.asarray(a[:, 2]).copy()
                   for a in jax.tree.leaves(eng.t_segs)]
    len2 = int(eng.committed_len[2])
    pending2 = int(eng.pending[2])
    toks, n_acc = eng.step_group([0, 1], SHORT)
    assert toks.shape[0] == 2 and n_acc.shape == (2,)
    for b, a in zip(row2_before,
                    [np.asarray(a[:, 2]) for a in jax.tree.leaves(eng.t_segs)]):
        np.testing.assert_array_equal(b, a)
    assert int(eng.committed_len[2]) == len2
    assert int(eng.pending[2]) == pending2
    assert bool(eng._admit_mask[2])          # row 2's admission reset intact
    assert not eng._admit_mask[0] and not eng._admit_mask[1]   # consumed
    assert int(eng.committed_len[0]) > len(PROMPTS[0]) - 1
    assert int(eng.committed_len[1]) > len(PROMPTS[1]) - 1
    # row 2 still steps correctly from its admitted state afterwards
    eng.step_group([2], LONG)
    assert int(eng.committed_len[2]) > len2
    np.testing.assert_array_equal(np.asarray(eng.t_len), eng.committed_len)


def test_group_layout_cache_memoizes_and_is_readonly():
    """Satellite: ``overlap.group_queries`` is memoized by (T, C) — the
    fused-verify prep layer calls it per layer per step — and hands out a
    read-only array so callers cannot corrupt the shared copy."""
    overlap.group_queries.cache_clear()
    q1, pad1 = overlap.group_queries(7, 2)
    q2, pad2 = overlap.group_queries(7, 2)
    assert q1 is q2 and pad1 == pad2
    info = overlap.group_queries.cache_info()
    assert info.hits == 1 and info.misses == 1
    assert not q1.flags.writeable
    with pytest.raises(ValueError):
        q1[0, 0] = 99
    np.testing.assert_array_equal(q1[-1], [6, 6])       # clamped padding


def test_kernel_cache_stats_exposed(bk_pair):
    """Satellite: hit/miss counters of the kernel build cache and the layout
    cache ride in engine metrics alongside kv_cache_bytes."""
    from repro.kernels.nsa_verify import ops as nsa_ops
    info = nsa_ops.verify_call_cache_info()
    assert info.maxsize >= 1024
    tp, tcfg, dp, dcfg = bk_pair
    eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve())
    stats = eng.kernel_cache_stats()
    for key in ("verify_call_hits", "verify_call_misses",
                "verify_call_cached", "group_layout_hits",
                "group_layout_misses", "group_layout_cached"):
        assert key in stats
    assert eng.kv_cache_bytes() == 0      # not started — but both metrics
    # coexist on the engine; the batched engine adds its step cache
    beng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve())
    bstats = beng.kernel_cache_stats()
    assert {"step_cache_hits", "step_cache_misses",
            "step_cache_cached"} <= set(bstats)
