"""Batched-serving hot-path tests: BatchedSSVEngine == looped SSVEngine,
host-transfer bounds of the fused step, value-hashed jit cache keys, and
no-op commits for frozen (finished) rows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, NSAConfig, ServeConfig, SSVConfig
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.models import model

NSA = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4, window=32)


@pytest.fixture(scope="module")
def nsa_pair():
    tcfg = ModelConfig(name="btgt", num_layers=2, d_model=96, num_heads=4,
                       num_kv_heads=2, d_ff=192, vocab_size=128,
                       max_seq_len=512, dtype="float32", attention="nsa",
                       nsa=NSA)
    dcfg = draft_lib.draft_config(tcfg, num_layers=1)
    tp = model.init(jax.random.PRNGKey(0), tcfg)
    dp = model.init(jax.random.PRNGKey(1), dcfg)
    return tp, tcfg, dp, dcfg


def _serve(ssv, n, temperature=0.0):
    return ServeConfig(max_new_tokens=n, temperature=temperature,
                       max_context=256, ssv=ssv, use_planner=False)


def test_batched_equals_looped_sequential(nsa_pair):
    """Token equality: a batch of prompts through the vectorized engine must
    reproduce each prompt's single-stream greedy output exactly — including
    divergent per-request lengths and completion times."""
    tp, tcfg, dp, dcfg = nsa_pair
    ssv = SSVConfig(tree_depth=2, tree_width=2)
    n = 10
    prompts = [np.arange(20) % 128, (np.arange(26) * 3) % 128,
               (np.arange(17) * 7) % 128]
    seq = []
    for p in prompts:
        eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve(ssv, n))
        seq.append(eng.generate(p, max_new_tokens=n).tokens)
    beng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve(ssv, n))
    bres = beng.generate_batch(prompts, max_new_tokens=n)
    assert len(bres.results) == len(prompts)
    for i, r in enumerate(bres.results):
        np.testing.assert_array_equal(seq[i], r.tokens)
    # true batching: the whole batch advanced in at most max_new fused steps
    assert bres.steps <= n


def test_batched_completion_masks_freeze_rows(nsa_pair):
    """Rows that finish early must stop committing: their tracked length is
    frozen while the rest of the batch keeps generating."""
    tp, tcfg, dp, dcfg = nsa_pair
    ssv = SSVConfig(tree_depth=2, tree_width=2)
    beng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve(ssv, 6))
    prompts = [np.arange(20) % 128, (np.arange(24) * 5) % 128]
    beng.start([np.asarray(p) for p in prompts])
    len0 = beng.committed_len.copy()
    beng.step(active=np.array([False, True]))
    assert beng.committed_len[0] == len0[0]          # frozen row unchanged
    assert beng.committed_len[1] > len0[1]
    # device lengths agree with the host mirror
    np.testing.assert_array_equal(np.asarray(beng.t_len), beng.committed_len)


def test_step_host_transfer_excludes_logits(nsa_pair):
    """The per-step device->host traffic of the spec-decode loop must be a
    few ints (path tokens + counts + bonus), NOT the (T, vocab) logits."""
    tp, tcfg, dp, dcfg = nsa_pair
    ssv = SSVConfig(tree_depth=3, tree_width=2)
    T = ssv.num_draft_tokens() + 1
    assert engine_lib.step_host_transfer_elems(ssv) < T * tcfg.vocab_size / 100
    eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve(ssv, 8))
    res = eng.generate(np.arange(16) % 128, max_new_tokens=8)
    for st in res.steps:
        assert st.host_elems <= engine_lib.step_host_transfer_elems(ssv)
    # and the fused step's host-facing outputs really are that small: check
    # the abstract output shapes of the jitted function
    fn = engine_lib.jit_verify_accept(tcfg, ssv, True, 0.0)
    eng2 = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve(ssv, 8))
    eng2.start(np.arange(16) % 128)
    tokens = jnp.zeros((1, T), jnp.int32)
    out_shapes = jax.eval_shape(fn, tp, eng2.t_caches, tokens)
    _, path_s, toks_s, bonus_s, nacc_s = out_shapes
    host_elems = (np.prod(path_s.shape) + np.prod(toks_s.shape)
                  + np.prod(bonus_s.shape or (1,)) + np.prod(nacc_s.shape or (1,)))
    assert host_elems < T * tcfg.vocab_size / 100


def test_jit_cache_keys_by_value(nsa_pair):
    """Frozen config dataclasses hash by value: equal configs must map to the
    same compiled step so planner strategy switches never recompile a
    previously-seen (config, strategy, topology) inside a generation."""
    tp, tcfg, dp, dcfg = nsa_pair
    ssv_a = SSVConfig(tree_depth=3, tree_width=2, refresh_schedule=(1,))
    ssv_b = SSVConfig(tree_depth=3, tree_width=2, refresh_schedule=(1,))
    assert ssv_a == ssv_b and hash(ssv_a) == hash(ssv_b)
    cfg_copy = ModelConfig(**{**tcfg.__dict__})
    assert cfg_copy == tcfg and hash(cfg_copy) == hash(tcfg)
    assert engine_lib.jit_verify_accept(tcfg, ssv_a, True, 0.0) is \
        engine_lib.jit_verify_accept(cfg_copy, ssv_b, True, 0.0)
    assert engine_lib.jit_verify(tcfg, ssv_a) is engine_lib.jit_verify(cfg_copy, ssv_b)
    assert engine_lib.jit_batched_step(tcfg, dcfg, ssv_a, True, 0.0) is \
        engine_lib.jit_batched_step(tcfg, dcfg, ssv_b, True, 0.0)
    # different strategy (different topology) -> different cache entry
    ssv_c = SSVConfig(tree_depth=2, tree_width=2, refresh_schedule=(1,))
    assert engine_lib.jit_verify_accept(tcfg, ssv_c, True, 0.0) is not \
        engine_lib.jit_verify_accept(tcfg, ssv_a, True, 0.0)


def test_commit_zero_is_noop_for_recurrent_state():
    """commit with n_accepted == 0 must preserve recurrent states and length
    (the frozen-row contract batched serving relies on)."""
    from repro.config import RecurrentConfig
    cfg = ModelConfig(name="r", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=0, vocab_size=32, max_seq_len=128,
                      dtype="float32", block_pattern=("mlstm",),
                      recurrent=RecurrentConfig(kind="mlstm", num_heads=2))
    params = model.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.arange(8)[None] % 32, jnp.int32)
    _, caches = model.prefill(params, cfg, toks, max_len=64)
    T = 3
    positions = (8 + jnp.arange(T))[None].astype(jnp.int32)
    tmask = jnp.asarray(np.tril(np.ones((T, T), bool)))[None]
    parents = jnp.asarray(np.arange(T) - 1, jnp.int32)
    _, updates = model.verify_step(params, cfg, caches, toks[:, :T], positions,
                                   tmask, parents)
    frozen = model.commit(params, cfg, caches, updates,
                          accepted=jnp.zeros((1, T), jnp.int32),
                          n_accepted=jnp.zeros((1,), jnp.int32))
    assert int(frozen["length"]) == int(caches["length"])
    for a, b in zip(jax.tree.leaves(caches["segments"]),
                    jax.tree.leaves(frozen["segments"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_start_rejects_empty_prompt_list(nsa_pair):
    """Regression: an empty batch used to die on a bare assert (or worse,
    propagate into a zero-row stack); it must be a clear ValueError."""
    tp, tcfg, dp, dcfg = nsa_pair
    beng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg,
                                       _serve(SSVConfig(tree_depth=2,
                                                        tree_width=2), 4))
    with pytest.raises(ValueError, match="empty"):
        beng.start([])
    with pytest.raises(ValueError, match="empty"):
        beng.generate_batch([])


def test_start_rejects_prompt_over_max_context(nsa_pair):
    """Regression: a prompt longer than max_context used to fail deep inside
    prefill with a shape assert; it must be a clear ValueError naming the
    limit."""
    tp, tcfg, dp, dcfg = nsa_pair
    ssv = SSVConfig(tree_depth=2, tree_width=2)
    serve = _serve(ssv, 4)                                     # max_context=256
    beng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, serve)
    ok = np.arange(20) % 128
    with pytest.raises(ValueError, match="max_context"):
        beng.start([ok, np.arange(serve.max_context + 1) % 128])
    with pytest.raises(ValueError, match="empty"):
        beng.start([ok, np.array([], np.int64)])
    # boundary: a prompt that fits the cache but leaves no room for even one
    # speculative step would let the first commit write past the cache end —
    # it must be rejected at admission, not corrupt KV silently
    with pytest.raises(ValueError, match="headroom"):
        beng.start([np.arange(serve.max_context) % 128])
    # ... while a prompt that leaves exactly one step of headroom is fine
    limit = serve.max_context + 1 - 2 * (ssv.num_draft_tokens() + 2)
    beng.start([np.arange(limit) % 128])


def test_batched_stochastic_runs(nsa_pair):
    tp, tcfg, dp, dcfg = nsa_pair
    ssv = SSVConfig(tree_depth=2, tree_width=2)
    beng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg,
                                       _serve(ssv, 6, temperature=0.7))
    res = beng.generate_batch([np.arange(16) % 128, np.arange(18) % 128],
                              max_new_tokens=6)
    for r in res.results:
        assert len(r.tokens) >= 6
        assert all(0 <= t < tcfg.vocab_size for t in r.tokens)
