"""Property-style tests for the continuous-batching scheduler
(repro.core.schedule.Scheduler): no slot double-assignment, FIFO fairness
under equal arrivals, freed-slot reuse, and queue drainage.

Hypothesis-optional shim (PR 2 pattern): when hypothesis is installed the
properties run under ``@given`` with full shrinking; on container images
without it they fall back to a seeded sweep (pytest parametrize over seeds)
instead of skipping, so the invariants stay in tier-1 either way.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:   # container images without hypothesis: seeded fallback
    HAVE_HYPOTHESIS = False

from repro.core import schedule as S


def seeded_property(n_examples=30, seed_max=10_000):
    """@given(seed=...) under hypothesis; a seeded parametrized sweep
    without it. The test body must derive all randomness from ``seed``."""
    if HAVE_HYPOTHESIS:
        def deco(fn):
            return settings(max_examples=n_examples, deadline=None)(
                given(seed=st.integers(0, seed_max))(fn))
        return deco

    def deco(fn):
        return pytest.mark.parametrize("seed", range(n_examples))(fn)
    return deco


def _mk_requests(rng, n, max_arrival=6):
    return [S.Request(req_id=i, prompt=np.arange(3 + i),
                      arrival=float(rng.integers(0, max_arrival)))
            for i in range(n)]


def _drive(sched, rng, max_rounds=500):
    """Random-but-seeded serving simulation: each round admits arrived
    requests, then finishes a random subset of decoding slots. Returns the
    per-round admission log [(round, slot, req_id)]."""
    log = []
    for rnd in range(max_rounds):
        if sched.idle():
            break
        for slot, req in sched.admit(float(rnd)):
            assert sched.states[slot] is S.SlotState.PREFILLING
            log.append((rnd, slot, req.req_id))
            sched.mark_decoding(slot)
        decoding = np.nonzero(sched.decoding_mask())[0]
        for slot in decoding:
            if rng.random() < 0.5:
                sched.finish(int(slot), float(rnd) + 1.0)
                sched.release(int(slot))
    return log


@seeded_property()
def test_no_slot_double_assignment(seed):
    """A slot is never assigned while occupied, and a request is admitted
    exactly once."""
    rng = np.random.default_rng(seed)
    n_slots = int(rng.integers(1, 5))
    sched = S.Scheduler(n_slots)
    reqs = _mk_requests(rng, int(rng.integers(1, 12)))
    for r in reqs:
        sched.submit(r)
    occupied = {}
    admitted = []
    for rnd in range(400):
        if sched.idle():
            break
        for slot, req in sched.admit(float(rnd)):
            assert slot not in occupied, \
                f"slot {slot} double-assigned while holding {occupied[slot]}"
            occupied[slot] = req.req_id
            admitted.append(req.req_id)
            sched.mark_decoding(slot)
        for slot in np.nonzero(sched.decoding_mask())[0]:
            if rng.random() < 0.4:
                sched.finish(int(slot), float(rnd) + 1.0)
                sched.release(int(slot))
                del occupied[int(slot)]
    assert sorted(admitted) == sorted(r.req_id for r in reqs)
    assert len(admitted) == len(set(admitted))


@seeded_property()
def test_fifo_fairness_under_equal_arrivals(seed):
    """With identical arrival times, requests are admitted in submission
    order (no overtaking)."""
    rng = np.random.default_rng(seed)
    n_slots = int(rng.integers(1, 4))
    sched = S.Scheduler(n_slots)
    n = int(rng.integers(2, 10))
    for i in range(n):
        sched.submit(S.Request(req_id=i, prompt=np.arange(4), arrival=0.0))
    log = _drive(sched, rng)
    order = [req_id for _, _, req_id in log]
    assert order == sorted(order), f"FIFO violated: admission order {order}"


@seeded_property()
def test_earlier_arrivals_never_overtaken(seed):
    """General arrivals: when request ``a`` is admitted, no strictly
    earlier-arrived request can still be waiting in the queue and only get a
    slot in a later round (earliest-arrival pop)."""
    rng = np.random.default_rng(seed)
    sched = S.Scheduler(int(rng.integers(1, 4)))
    reqs = _mk_requests(rng, int(rng.integers(2, 10)))
    by_id = {r.req_id: r for r in reqs}
    for r in reqs:
        sched.submit(r)
    log = _drive(sched, rng)
    admitted_at = {req_id: rnd for rnd, _, req_id in log}
    for a in reqs:
        for b in reqs:
            if a.req_id == b.req_id:
                continue
            # b arrived strictly earlier and was already in the arrived queue
            # when a was admitted -> b must not be admitted strictly later
            if (b.arrival < a.arrival
                    and b.arrival <= admitted_at[a.req_id]):
                assert admitted_at[b.req_id] <= admitted_at[a.req_id], (
                    f"req {b.req_id} (arrival {b.arrival}) overtaken by "
                    f"req {a.req_id} (arrival {a.arrival})")


@seeded_property()
def test_freed_slot_reuse_and_drain(seed):
    """More requests than slots: freed slots are reused, every request is
    eventually served, and the scheduler drains to idle."""
    rng = np.random.default_rng(seed)
    n_slots = int(rng.integers(1, 3))
    sched = S.Scheduler(n_slots)
    reqs = _mk_requests(rng, n_slots + int(rng.integers(1, 8)))
    for r in reqs:
        sched.submit(r)
    log = _drive(sched, rng)
    assert sched.idle()
    assert len(sched.queue) == 0
    assert len(sched.completed) == len(reqs)
    for r in reqs:
        assert r.admitted_at is not None and r.finished_at is not None
        assert r.queue_delay >= 0.0
    # reuse: with fewer slots than requests, some slot served >= 2 requests
    slots_used = [slot for _, slot, _ in log]
    assert max(np.bincount(slots_used)) >= 2
    assert all(0 <= s < n_slots for s in slots_used)


def test_invalid_transitions_raise():
    sched = S.Scheduler(2)
    sched.submit(S.Request(req_id=0, prompt=np.arange(4)))
    [(slot, _)] = sched.admit(0.0)
    with pytest.raises(RuntimeError):        # finish before decoding
        sched.finish(slot, 1.0)
    sched.mark_decoding(slot)
    with pytest.raises(RuntimeError):        # double mark_decoding
        sched.mark_decoding(slot)
    with pytest.raises(RuntimeError):        # release before finish
        sched.release(slot)
    sched.finish(slot, 1.0)
    sched.release(slot)
    assert sched.idle()
    with pytest.raises(ValueError):
        S.Scheduler(0)


def test_arrivals_gate_admission():
    """A request is invisible to admission until its arrival time."""
    sched = S.Scheduler(2)
    sched.submit(S.Request(req_id=0, prompt=np.arange(4), arrival=3.0))
    assert sched.admit(0.0) == []
    assert sched.next_arrival() == 3.0
    placed = sched.admit(3.0)
    assert [r.req_id for _, r in placed] == [0]
    assert placed[0][1].queue_delay == 0.0


def test_poisson_arrivals_deterministic():
    a = S.poisson_arrivals(6, 0.5, seed=7)
    b = S.poisson_arrivals(6, 0.5, seed=7)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) > 0).all()
    np.testing.assert_array_equal(S.poisson_arrivals(4, 0.0), np.zeros(4))


# ------------------------------------------------- bucket-aware admission
def _bucket_of(req):
    """Context bucket by prompt length: short (< 8 tokens) = 0, long = 1."""
    return 0 if len(req.prompt) < 8 else 1


def _req(req_id, length, arrival=0.0):
    return S.Request(req_id=req_id, prompt=np.arange(length), arrival=arrival)


def test_bucket_policy_prefers_live_bucket():
    """Filling a freed slot under policy='bucket' admits the earliest
    arrived request whose bucket already has live rows — keeping execution
    groups homogeneous — even when a different-bucket request arrived
    earlier. Plain FIFO (the default) admits strictly by arrival."""
    for policy, expect in (("bucket", [2, 1]), ("fifo", [1, 2])):
        sched = S.Scheduler(2, bucket_of=_bucket_of, policy=policy)
        sched.submit(_req(0, 4, arrival=0.0))            # short -> slot 0
        [(s0, r0)] = sched.admit(0.0)
        sched.mark_decoding(s0)
        assert r0.req_id == 0
        sched.submit(_req(1, 16, arrival=1.0))           # long, earlier
        sched.submit(_req(2, 5, arrival=2.0))            # short, later
        placed = sched.admit(2.0)
        assert [r.req_id for _, r in placed] == [expect[0]]
        sched.mark_decoding(placed[0][0])
        # the next freed slot takes the remaining request either way
        sched.finish(s0, 3.0)
        sched.release(s0)
        placed = sched.admit(3.0)
        assert [r.req_id for _, r in placed] == [expect[1]]


def test_bucket_policy_falls_back_to_fifo_head():
    """No live-bucket match (or an empty batch): the FIFO head admits, so
    new buckets open instead of starving."""
    sched = S.Scheduler(2, bucket_of=_bucket_of, policy="bucket")
    sched.submit(_req(0, 16, arrival=0.0))               # long into empty batch
    [(s0, r0)] = sched.admit(0.0)
    assert r0.req_id == 0
    sched.mark_decoding(s0)
    sched.submit(_req(1, 4, arrival=1.0))                # short: no live short
    placed = sched.admit(1.0)
    assert [r.req_id for _, r in placed] == [1]


def test_bucket_occupancy_stats():
    sched = S.Scheduler(4, bucket_of=_bucket_of, policy="bucket")
    assert sched.bucket_occupancy() == {}
    for i, length in enumerate((4, 5, 16)):
        sched.submit(_req(i, length))
    for slot, _ in sched.admit(0.0):
        sched.mark_decoding(slot)
    occ = sched.bucket_occupancy()
    assert occ == {0: 0.5, 1: 0.25}
    # no classifier -> no stats (and the default policy stays plain FIFO)
    assert S.Scheduler(2).bucket_occupancy() == {}


def test_bucket_policy_validation():
    with pytest.raises(ValueError, match="bucket_of"):
        S.Scheduler(2, policy="bucket")
    with pytest.raises(ValueError, match="policy"):
        S.Scheduler(2, policy="sjf")
