"""Shape/dtype sweeps of the fused NSA verification Pallas kernel
(interpret=True) against the pure-jnp oracle, plus equivalence of the
kernel-backed layer paths against the model-level reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, NSAConfig
from repro.kernels.nsa_verify import ops, ref
from repro.models import model, nsa as nsa_lib

NSA = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4, window=32)


def make_inputs(rng, B, T, Hq, Hkv, Dh, S, prefix, dtype=jnp.float32):
    def r(*shape):
        return jnp.asarray(rng.normal(size=shape), dtype)
    ncb = (S - NSA.cmp_block) // NSA.cmp_stride + 1
    ncb_valid = max(0, (prefix - NSA.cmp_block) // NSA.cmp_stride + 1)
    q = r(B, T, Hq, Dh) / np.sqrt(Dh)
    kc, vc = r(B, S, Hkv, Dh), r(B, S, Hkv, Dh)
    kcmp, vcmp = r(B, ncb, Hkv, Dh), r(B, ncb, Hkv, Dh)
    kd, vd = r(B, T, Hkv, Dh), r(B, T, Hkv, Dh)
    gates = jax.nn.sigmoid(r(B, T, 3, Hq)).astype(jnp.float32)
    depths = np.minimum(np.arange(T), 3)
    positions = jnp.asarray(prefix + depths)[None].repeat(B, 0)
    max_blk = max(prefix // NSA.sel_block, 1)
    sel_idx = jnp.asarray(np.sort(rng.integers(0, max_blk, (B, T, Hkv, NSA.n_selected)),
                                  axis=-1), jnp.int32)
    sel_valid = jnp.asarray(rng.random((B, T, Hkv, NSA.n_selected)) < 0.9)
    tm = np.tril(np.ones((T, T), bool))
    tree_mask = jnp.asarray(tm)[None].repeat(B, 0)
    return (q, kc, vc, kcmp, vcmp, kd, vd, sel_idx, sel_valid, positions,
            prefix, ncb_valid, tree_mask, gates)


@pytest.mark.parametrize("B,T,Hq,Hkv,Dh,S,prefix", [
    (1, 4, 2, 1, 16, 64, 48),
    (2, 6, 4, 2, 32, 128, 100),
    (1, 8, 8, 4, 64, 96, 70),
    (2, 3, 6, 3, 16, 80, 33),   # prefix barely past one cmp block
])
@pytest.mark.parametrize("C,mode", [(1, "exact"), (2, "exact"), (3, "exact"),
                                    (2, "approx"), (4, "approx")])
def test_kernel_matches_oracle(B, T, Hq, Hkv, Dh, S, prefix, C, mode):
    rng = np.random.default_rng(B * 100 + T)
    inp = make_inputs(rng, B, T, Hq, Hkv, Dh, S, prefix)
    (q, kc, vc, kcmp, vcmp, kd, vd, sel_idx, sel_valid, positions, pl, nv,
     tm, gates) = inp
    out_k = ops.nsa_verify_fused(q, kc, vc, kcmp, vcmp, kd, vd, sel_idx,
                                 sel_valid, positions, pl, nv, tm, gates, NSA,
                                 C=C, mode=mode)
    _, _, merged, mvalid, own, _, _ = ops.prepare_groups(
        q, gates, sel_idx, sel_valid, positions, C, mode, NSA.n_selected)
    out_r = ref.ref_verify_batched(
        q, kc, vc, kcmp, vcmp, kd, vd, jnp.where(mvalid > 0, merged, -1),
        own > 0, positions, pl, nv, tm, gates, group_size=C,
        sel_block=NSA.sel_block, cmp_block=NSA.cmp_block,
        cmp_stride=NSA.cmp_stride, window=NSA.window)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 3e-2)])
def test_kernel_dtypes(dtype, tol):
    rng = np.random.default_rng(7)
    inp = make_inputs(rng, 1, 4, 4, 2, 32, 96, 80, dtype=dtype)
    (q, kc, vc, kcmp, vcmp, kd, vd, sel_idx, sel_valid, positions, pl, nv,
     tm, gates) = inp
    out_k = ops.nsa_verify_fused(q, kc, vc, kcmp, vcmp, kd, vd, sel_idx,
                                 sel_valid, positions, pl, nv, tm, gates, NSA,
                                 C=2, mode="exact")
    _, _, merged, mvalid, own, _, _ = ops.prepare_groups(
        q, gates, sel_idx, sel_valid, positions, 2, "exact", NSA.n_selected)
    out_r = ref.ref_verify_batched(
        q, kc, vc, kcmp, vcmp, kd, vd, jnp.where(mvalid > 0, merged, -1),
        own > 0, positions, pl, nv, tm, gates, group_size=2,
        sel_block=NSA.sel_block, cmp_block=NSA.cmp_block,
        cmp_stride=NSA.cmp_stride, window=NSA.window)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("C,mode", [(1, "exact"), (2, "exact"), (2, "approx")])
@pytest.mark.parametrize("page_mult", [1, 2])
def test_fused_paged_matches_dense(C, mode, page_mult):
    """Paged-store execution of the fused kernel: the dense cache re-homed
    into a shuffled page pool (page_size = page_mult * sel_block) with the
    page table resolving selected blocks must reproduce the dense fused
    output exactly — including merged entries pointing at unmapped logical
    pages, which are masked, not clamped."""
    rng = np.random.default_rng(11 + page_mult)
    B, T, Hq, Hkv, Dh, S, prefix = 2, 6, 4, 2, 32, 128, 100
    inp = make_inputs(rng, B, T, Hq, Hkv, Dh, S, prefix)
    (q, kc, vc, kcmp, vcmp, kd, vd, sel_idx, sel_valid, positions, pl, nv,
     tm, gates) = inp
    out_dense = ops.nsa_verify_fused(q, kc, vc, kcmp, vcmp, kd, vd, sel_idx,
                                     sel_valid, positions, pl, nv, tm, gates,
                                     NSA, C=C, mode=mode)
    # re-home the dense cache into a shuffled pool
    ps = NSA.sel_block * page_mult
    mp = S // ps
    P = B * mp + 3
    order = np.random.default_rng(5).permutation(P)[: B * mp]
    pages = jnp.asarray(order.reshape(B, mp).astype(np.int32))
    poolk = jnp.zeros((P, ps, Hkv, Dh))
    poolv = jnp.zeros((P, ps, Hkv, Dh))
    for b in range(B):
        poolk = poolk.at[order.reshape(B, mp)[b]].set(
            np.asarray(kc[b]).reshape(mp, ps, Hkv, Dh))
        poolv = poolv.at[order.reshape(B, mp)[b]].set(
            np.asarray(vc[b]).reshape(mp, ps, Hkv, Dh))
    out_paged = ops.nsa_verify_fused(q, poolk, poolv, kcmp, vcmp, kd, vd,
                                     sel_idx, sel_valid, positions, pl, nv,
                                     tm, gates, NSA, C=C, mode=mode,
                                     page_table=pages)
    np.testing.assert_allclose(np.asarray(out_paged), np.asarray(out_dense),
                               rtol=2e-5, atol=2e-6)
    # an unmapped page (hole at logical page 0 — inside the prefix, outside
    # the trailing window) masks its selection blocks out exactly like
    # sel_valid=False would on the dense layout: masked, never clamped
    holey = pages.at[:, 0].set(-1)
    bpp = ps // NSA.sel_block
    safe = jnp.clip(sel_idx, bpp, None)          # keep other slots off page 0
    hostile = safe.at[..., 0].set(0)             # block 0 lives in the hole
    out_holey = ops.nsa_verify_fused(q, poolk, poolv, kcmp, vcmp, kd, vd,
                                     hostile, sel_valid, positions, pl, nv,
                                     tm, gates, NSA, C=C, mode=mode,
                                     page_table=holey)
    out_masked = ops.nsa_verify_fused(q, kc, vc, kcmp, vcmp, kd, vd, hostile,
                                      sel_valid.at[..., 0].set(False),
                                      positions, pl, nv, tm, gates, NSA,
                                      C=C, mode=mode)
    np.testing.assert_allclose(np.asarray(out_holey), np.asarray(out_masked),
                               rtol=2e-5, atol=2e-6)


@pytest.fixture(scope="module")
def nsa_model():
    cfg = ModelConfig(name="t", num_layers=1, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=97, dtype="float32",
                      attention="nsa", nsa=NSA)
    key = jax.random.PRNGKey(0)
    p = model.init(key, cfg)
    bp = jax.tree.map(lambda a: a[0], p["segments"][0][0])
    toks = jax.random.randint(key, (2, 100), 0, 97)
    _, caches = model.prefill(p, cfg, toks, max_len=160)
    cache = jax.tree.map(lambda a: a[0], caches["segments"][0][0])
    return cfg, bp, cache


def _tree_inputs(key, cfg, prefix, T=5):
    x = jax.random.normal(key, (2, T, cfg.d_model))
    parents = [-1, 0, 0, 1, 2]
    depths = [0, 1, 1, 2, 2]
    positions = jnp.asarray(prefix + np.asarray(depths))[None].repeat(2, 0)
    tm = np.zeros((T, T), bool)
    for i in range(T):
        j = i
        while j >= 0:
            tm[i, j] = True
            j = parents[j]
    return x, positions, jnp.asarray(tm)[None].repeat(2, 0)


def test_refresh_layer_matches_model_ref(nsa_model):
    cfg, bp, cache = nsa_model
    x, positions, tm = _tree_inputs(jax.random.PRNGKey(1), cfg, 100)
    out_ref, _, (si, sv) = nsa_lib.nsa_verify_ref(
        bp["mix"], cfg, x, cache["kv"], cache["cmp"], 100, positions, tm)
    out_k, _, (si2, _) = ops.nsa_verify_kernel_layer(
        bp["mix"], cfg, x, cache["kv"], cache["cmp"], 100, positions, tm,
        C=2, mode="exact", reuse=False)
    assert (si == si2).all()
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_ref, np.float32),
                               rtol=1e-4, atol=1e-5)


def test_reuse_layer_matches_model_ref(nsa_model):
    cfg, bp, cache = nsa_model
    x, positions, tm = _tree_inputs(jax.random.PRNGKey(2), cfg, 100)
    _, _, (si, sv) = nsa_lib.nsa_verify_ref(
        bp["mix"], cfg, x, cache["kv"], cache["cmp"], 100, positions, tm)
    out_ref = nsa_lib.nsa_verify_ref(
        bp["mix"], cfg, x, cache["kv"], cache["cmp"], 100, positions, tm,
        sel_idx=si, sel_valid=sv)[0]
    out_k, _, _ = ops.nsa_verify_kernel_layer(
        bp["mix"], cfg, x, cache["kv"], cache["cmp"], 100, positions, tm,
        sel_idx=si, sel_valid=sv, C=2, mode="exact", reuse=True)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_ref, np.float32),
                               rtol=1e-4, atol=1e-5)


def test_vanilla_baseline_matches_model_ref(nsa_model):
    cfg, bp, cache = nsa_model
    x, positions, tm = _tree_inputs(jax.random.PRNGKey(3), cfg, 100)
    out_ref = nsa_lib.nsa_verify_ref(
        bp["mix"], cfg, x, cache["kv"], cache["cmp"], 100, positions, tm)[0]
    out_v, _, _ = ops.nsa_verify_vanilla_layer(
        bp["mix"], cfg, x, cache["kv"], cache["cmp"], 100, positions, tm)
    np.testing.assert_allclose(np.asarray(out_v, np.float32),
                               np.asarray(out_ref, np.float32),
                               rtol=1e-4, atol=1e-5)
