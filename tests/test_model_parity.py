"""Strong integration parity: teacher-forced forward_train logits must match
the prefill + decode_step chain token by token, for every attention family.
This pins train/serve consistency — the invariant that makes speculative
verification against the training-mode semantics sound."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, NSAConfig, RecurrentConfig
from repro.models import model

NSA = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4, window=32)


def make_cfg(kind):
    base = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                d_ff=128, vocab_size=97, dtype="float32", nsa=NSA,
                max_seq_len=256)
    if kind == "dense":
        return ModelConfig(name="dense", **base)
    if kind == "swa":
        return ModelConfig(name="swa", attention="swa", window=24, **base)
    if kind == "nsa":
        return ModelConfig(name="nsa", attention="nsa", **base)
    if kind == "rglru":
        return ModelConfig(name="rglru", block_pattern=("rglru", "attn"),
                           recurrent=RecurrentConfig(kind="rglru"), **base)
    if kind == "xlstm":
        return ModelConfig(name="xlstm", block_pattern=("mlstm", "slstm"),
                           recurrent=RecurrentConfig(kind="mlstm", num_heads=4),
                           **{**base, "d_ff": 0})
    raise ValueError(kind)


@pytest.mark.parametrize("kind", ["dense", "swa", "nsa", "rglru", "xlstm"])
def test_train_decode_parity(kind):
    cfg = make_cfg(kind)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    S = 48
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab_size)

    hidden, _, _ = model.forward_train(params, cfg, toks, remat=False)
    logits_train = model.logits_fn(params, cfg, hidden)          # (1, S, V)

    n0 = 24
    _, caches = model.prefill(params, cfg, toks[:, :n0], max_len=96)
    outs = []
    for t in range(n0, S):
        logits, caches = model.decode_step(params, cfg, caches, toks[:, t:t + 1])
        outs.append(np.asarray(logits[0, 0]))
    got = np.stack(outs)                                         # (S-n0, V)
    want = np.asarray(logits_train[0, n0:S])
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("kind", ["dense", "nsa", "xlstm"])
def test_verify_equals_decode_chain(kind):
    """A chain-tree verification must reproduce sequential decode over the
    same tokens. For dense/recurrent this is exact (full logits match). For
    NSA, draft nodes deeper than the root route their selection branch over
    the *committed* prefix only (the paper's verification semantics), while
    sequential decode sees the grown cache — the root node is exact and
    deeper nodes must agree in argmax (which is what greedy acceptance uses;
    the window branch covers the trailing tokens exactly)."""
    from repro.core.tree import chain_topology, positions_for
    cfg = make_cfg(kind)
    key = jax.random.PRNGKey(1)
    params = model.init(key, cfg)
    toks = jax.random.randint(key, (1, 40), 0, cfg.vocab_size)
    _, c1 = model.prefill(params, cfg, toks[:, :32], max_len=96)
    _, c2 = model.prefill(params, cfg, toks[:, :32], max_len=96)
    chain = toks[:, 32:37]                                       # 5 tokens

    # path A: verify the 5 tokens as a rooted chain tree
    topo = chain_topology(4)
    positions = jnp.asarray(positions_for(topo, 32))[None]
    tm = jnp.asarray(topo.mask)[None]
    logits_v, _ = model.verify_step(params, cfg, c1, chain, positions, tm,
                                    jnp.asarray(topo.parents))

    # path B: sequential decode
    outs = []
    for t in range(5):
        lg, c2 = model.decode_step(params, cfg, c2, chain[:, t:t + 1])
        outs.append(np.asarray(lg[0, 0]))
    got = np.asarray(logits_v[0])
    want = np.stack(outs)
    if kind == "nsa":
        # root node: bitwise-equal to decode (same committed prefix)
        np.testing.assert_allclose(got[0], want[0], rtol=2e-3, atol=2e-3)
        assert got[0].argmax() == want[0].argmax()
        # deeper nodes: close but not identical on an UNTRAINED model whose
        # logit gaps are ~the approximation size; on trained models Strict
        # generation equality holds end-to-end (tests/test_engine.py)
        assert float(np.abs(got - want).max()) < 0.5
        agree = (got.argmax(-1) == want.argmax(-1)).mean()
        assert agree >= 0.6, agree
    else:
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
