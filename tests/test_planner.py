"""Planner tests: profile table, Algorithm-1 guard constants, refinement."""
import numpy as np
import pytest

from repro.config import SSVConfig
from repro.core import planner as P


def _flat_profile(expected_accept=4.0, n=3):
    entries = [P.ProfileEntry(
        SSVConfig(tree_depth=3 + i, tree_width=2, precision_class="Strict"),
        expected_accept - 0.5 * i, 0.01 + 0.001 * i) for i in range(n)]
    table = {(b, pc): list(entries) for b in range(4)
             for pc in P.PRECISION_CLASSES}
    return P.Profile(table=table)


def test_candidate_strategies_respect_class():
    for pc in P.PRECISION_CLASSES:
        mode, reuse = P.class_constraints(pc)
        for s in P.candidate_strategies(pc, num_layers=8):
            assert s.group_mode == mode
            assert (len(s.refresh_schedule) > 0) == reuse
            assert s.precision_class == pc
            assert 0 not in s.refresh_schedule  # layer 0 always refreshes


def test_guard_triggers_after_warmup_and_hysteresis():
    pl = P.RuntimePlanner(_flat_profile(expected_accept=4.0), "Strict")
    pl.begin_request(context_len=100)
    assert pl.rank == 0
    # 7 bad steps: below warmup m=8 -> no switch
    for _ in range(7):
        pl.observe(accepted=0, latency_s=0.01)
    assert pl.rank == 0
    # reach warmup, then h=5 consecutive below-threshold steps
    for _ in range(6):
        pl.observe(accepted=0, latency_s=0.01)
    assert pl.rank == 1
    assert pl.refinement_events == 1


def test_guard_not_triggered_when_acceptance_good():
    pl = P.RuntimePlanner(_flat_profile(expected_accept=4.0), "Strict")
    pl.begin_request(context_len=100)
    for _ in range(40):
        pl.observe(accepted=4, latency_s=0.01)
    assert pl.rank == 0 and pl.refinement_events == 0


def test_max_two_transitions():
    pl = P.RuntimePlanner(_flat_profile(expected_accept=10.0, n=5), "Strict")
    pl.begin_request(context_len=100)
    for _ in range(64):
        pl.observe(accepted=0, latency_s=0.01)
    assert pl.transitions <= 2
    # falls back to best explored rank
    assert pl.rank in (0, 1, 2)


def test_ema_alpha():
    pl = P.RuntimePlanner(_flat_profile(), "Strict")
    pl.begin_request(context_len=0)
    pl.observe(accepted=2, latency_s=0.01)
    pl.observe(accepted=4, latency_s=0.01)
    assert abs(pl.ema - (0.4 * 4 + 0.6 * 2)) < 1e-9


def test_profile_json_roundtrip():
    prof = _flat_profile()
    s = prof.to_json()
    prof2 = P.Profile.from_json(s)
    e1 = prof.lookup(100, "Strict")[0]
    e2 = prof2.lookup(100, "Strict")[0]
    assert e1.strategy == e2.strategy
    assert e1.expected_accept == e2.expected_accept


def test_bucket_of():
    assert P.bucket_of(0) == 0
    assert P.bucket_of(5000) == 1
    assert P.bucket_of(9000) == 2
    assert P.bucket_of(999999) == 3


def test_default_schedule_alternates():
    s = P.default_schedule(8)
    assert s == (1, 3, 5, 7)


# ---------------------------------------------------------------- BatchPlanner
def _bucketed_profile(expected_accept=4.0):
    """Two CPU-scale buckets with distinct ranked strategy lists."""
    buckets = ((0, 32), (32, 4096))
    mk = lambda D: SSVConfig(tree_depth=D, tree_width=2,
                             precision_class="Strict")
    table = {(0, "Strict"): [P.ProfileEntry(mk(1), expected_accept, 0.01),
                             P.ProfileEntry(mk(2), expected_accept, 0.02)],
             (1, "Strict"): [P.ProfileEntry(mk(3), expected_accept, 0.01),
                             P.ProfileEntry(mk(4), expected_accept, 0.02)]}
    return P.Profile(table=table, buckets=buckets)


def test_batch_planner_plan_groups_by_bucket():
    bp = P.BatchPlanner(_bucketed_profile(), "Strict")
    groups = bp.plan({3: 1, 0: 0, 2: 1, 1: 0})
    assert groups == [(0, [0, 1]), (1, [2, 3])]
    assert bp.plan({2: 1}) == [(1, [2])]
    assert bp.plan({}) == []


def test_batch_planner_strategy_per_bucket():
    bp = P.BatchPlanner(_bucketed_profile(), "Strict")
    assert bp.bucket_of(10) == 0 and bp.bucket_of(100) == 1
    assert bp.strategy_for(0).tree_depth == 1
    assert bp.strategy_for(1).tree_depth == 3


def test_batch_planner_guards_refine_independently():
    """Sustained low acceptance in ONE bucket walks only that bucket's guard
    to the next-ranked strategy — the other group's plan is untouched."""
    bp = P.BatchPlanner(_bucketed_profile(expected_accept=4.0), "Strict")
    for _ in range(P.WARMUP_M + P.HYSTERESIS_H):
        bp.observe(0, accepted=0.0, latency_s=0.01)
        bp.observe(1, accepted=4.0, latency_s=0.01)
    assert bp.strategy_for(0).tree_depth == 2      # refined to rank 1
    assert bp.strategy_for(1).tree_depth == 3      # still rank 0
    assert bp.refinement_events == 1


def test_batch_planner_begin_serve_resets_guards():
    bp = P.BatchPlanner(_bucketed_profile(), "Strict")
    for _ in range(P.WARMUP_M + P.HYSTERESIS_H):
        bp.observe(0, accepted=0.0, latency_s=0.01)
    assert bp.strategy_for(0).tree_depth == 2
    bp.begin_serve()
    assert bp.strategy_for(0).tree_depth == 1
    assert bp.refinement_events == 0


def test_batch_planner_rejects_uncovered_precision_class():
    """A profile that cannot plan the requested class for every bucket is a
    construction-time error, not a KeyError in the first serve round."""
    with pytest.raises(ValueError, match="Approx-only"):
        P.BatchPlanner(_bucketed_profile(), "Approx-only")
    prof = _bucketed_profile()
    del prof.table[(1, "Strict")]        # one bucket uncovered
    with pytest.raises(ValueError, match=r"bucket\(s\) \[1\]"):
        P.BatchPlanner(prof, "Strict")


def test_batch_planner_reachable_strategies():
    """The AOT warmup set: per bucket, the top rank plus every refinement
    hop the guard can take (max_transitions), deduplicated."""
    bp = P.BatchPlanner(_bucketed_profile(), "Strict")
    reach = bp.reachable_strategies()
    assert [s.tree_depth for s in reach] == [1, 2, 3, 4]
    bp1 = P.BatchPlanner(_bucketed_profile(), "Strict", max_transitions=0)
    assert [s.tree_depth for s in bp1.reachable_strategies()] == [1, 3]
