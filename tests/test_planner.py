"""Planner tests: profile table, Algorithm-1 guard constants, refinement."""
import numpy as np
import pytest

from repro.config import SSVConfig
from repro.core import planner as P


def _flat_profile(expected_accept=4.0, n=3):
    entries = [P.ProfileEntry(
        SSVConfig(tree_depth=3 + i, tree_width=2, precision_class="Strict"),
        expected_accept - 0.5 * i, 0.01 + 0.001 * i) for i in range(n)]
    table = {(b, pc): list(entries) for b in range(4)
             for pc in P.PRECISION_CLASSES}
    return P.Profile(table=table)


def test_candidate_strategies_respect_class():
    for pc in P.PRECISION_CLASSES:
        mode, reuse = P.class_constraints(pc)
        for s in P.candidate_strategies(pc, num_layers=8):
            assert s.group_mode == mode
            assert (len(s.refresh_schedule) > 0) == reuse
            assert s.precision_class == pc
            assert 0 not in s.refresh_schedule  # layer 0 always refreshes


def test_guard_triggers_after_warmup_and_hysteresis():
    pl = P.RuntimePlanner(_flat_profile(expected_accept=4.0), "Strict")
    pl.begin_request(context_len=100)
    assert pl.rank == 0
    # 7 bad steps: below warmup m=8 -> no switch
    for _ in range(7):
        pl.observe(accepted=0, latency_s=0.01)
    assert pl.rank == 0
    # reach warmup, then h=5 consecutive below-threshold steps
    for _ in range(6):
        pl.observe(accepted=0, latency_s=0.01)
    assert pl.rank == 1
    assert pl.refinement_events == 1


def test_guard_not_triggered_when_acceptance_good():
    pl = P.RuntimePlanner(_flat_profile(expected_accept=4.0), "Strict")
    pl.begin_request(context_len=100)
    for _ in range(40):
        pl.observe(accepted=4, latency_s=0.01)
    assert pl.rank == 0 and pl.refinement_events == 0


def test_max_two_transitions():
    pl = P.RuntimePlanner(_flat_profile(expected_accept=10.0, n=5), "Strict")
    pl.begin_request(context_len=100)
    for _ in range(64):
        pl.observe(accepted=0, latency_s=0.01)
    assert pl.transitions <= 2
    # falls back to best explored rank
    assert pl.rank in (0, 1, 2)


def test_ema_alpha():
    pl = P.RuntimePlanner(_flat_profile(), "Strict")
    pl.begin_request(context_len=0)
    pl.observe(accepted=2, latency_s=0.01)
    pl.observe(accepted=4, latency_s=0.01)
    assert abs(pl.ema - (0.4 * 4 + 0.6 * 2)) < 1e-9


def test_profile_json_roundtrip():
    prof = _flat_profile()
    s = prof.to_json()
    prof2 = P.Profile.from_json(s)
    e1 = prof.lookup(100, "Strict")[0]
    e2 = prof2.lookup(100, "Strict")[0]
    assert e1.strategy == e2.strategy
    assert e1.expected_accept == e2.expected_accept


def test_bucket_of():
    assert P.bucket_of(0) == 0
    assert P.bucket_of(5000) == 1
    assert P.bucket_of(9000) == 2
    assert P.bucket_of(999999) == 3


def test_default_schedule_alternates():
    s = P.default_schedule(8)
    assert s == (1, 3, 5, 7)
