"""Property tests for draft-tree topologies (hypothesis)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container images without hypothesis: skip, don't error
    pytest.skip("hypothesis not installed", allow_module_level=True)

from repro.core.tree import build_topology, chain_topology, positions_for


@given(depth=st.integers(1, 5), width=st.integers(1, 4),
       order=st.sampled_from(["bfs", "dfs"]),
       budget=st.integers(0, 20))
@settings(max_examples=60, deadline=None)
def test_topology_invariants(depth, width, order, budget):
    topo = build_topology(depth, width, order, budget)
    n = topo.num_nodes
    assert n >= 1
    assert topo.parents[0] == -1 and topo.depths[0] == 0  # pending root
    # topological: parents precede children
    for i in range(1, n):
        assert 0 <= topo.parents[i] < i
        assert topo.depths[i] == topo.depths[topo.parents[i]] + 1
    # mask = ancestor-or-self closure
    for i in range(n):
        anc = set()
        j = i
        while j >= 0:
            anc.add(j)
            j = int(topo.parents[j])
        assert set(np.where(topo.mask[i])[0]) == anc
    # budget honored (root excluded)
    if budget:
        assert n - 1 <= budget
    # mask is lower-triangular (flattening is causal)
    assert not np.triu(topo.mask, 1).any()


@given(depth=st.integers(1, 4), width=st.integers(2, 3))
@settings(max_examples=20, deadline=None)
def test_bfs_dfs_same_multiset(depth, width):
    """BFS and DFS orders contain the same (depth, parent-depth) multiset."""
    a = build_topology(depth, width, "bfs")
    b = build_topology(depth, width, "dfs")
    assert a.num_nodes == b.num_nodes
    assert sorted(a.depths.tolist()) == sorted(b.depths.tolist())


def test_dfs_parent_child_adjacency():
    topo = build_topology(3, 2, "dfs")
    # in DFS order every non-root node's parent is the immediately preceding
    # node OR an earlier ancestor on the current chain — first child is
    # always adjacent to its parent
    first_children = [i for i in range(1, topo.num_nodes)
                      if topo.parents[i] == i - 1]
    assert len(first_children) >= topo.depths.max()


def test_paths_cover_leaves():
    topo = build_topology(3, 2, "bfs")
    for row in topo.paths:
        valid = row[row >= 0]
        assert valid[0] == 0  # paths start at the root
        for a, b in zip(valid[:-1], valid[1:]):
            assert topo.parents[b] == a


def test_positions():
    topo = chain_topology(4)
    pos = positions_for(topo, 100)
    assert pos.tolist() == [100, 101, 102, 103, 104]
