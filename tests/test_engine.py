"""End-to-end engine tests: the Strict-mode exactness invariant (SSV output
== autoregressive greedy output), approx/reuse modes, recurrent-arch
speculation, and the trainer fault-tolerance loop."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (ModelConfig, NSAConfig, RecurrentConfig, ServeConfig,
                          SSVConfig, TrainConfig)
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.models import model

NSA = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4, window=32)


@pytest.fixture(scope="module")
def nsa_pair():
    tcfg = ModelConfig(name="tgt", num_layers=3, d_model=96, num_heads=4,
                       num_kv_heads=2, d_ff=192, vocab_size=128,
                       max_seq_len=512, dtype="float32", attention="nsa",
                       nsa=NSA)
    dcfg = draft_lib.draft_config(tcfg, num_layers=1)
    tp = model.init(jax.random.PRNGKey(0), tcfg)
    dp = model.init(jax.random.PRNGKey(1), dcfg)
    return tp, tcfg, dp, dcfg


def test_strict_equals_autoregressive(nsa_pair):
    tp, tcfg, dp, dcfg = nsa_pair
    prompt = np.arange(24) % 128
    n = 20
    ar = engine_lib.autoregressive_decode(tp, tcfg, prompt, n, 256)
    eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, ServeConfig(
        max_new_tokens=n, temperature=0.0, max_context=256,
        ssv=SSVConfig(tree_depth=3, tree_width=2, precision_class="Strict"),
        use_planner=False))
    res = eng.generate(prompt, max_new_tokens=n)
    m = min(len(ar.tokens), len(res.tokens))
    assert m >= n - 2
    np.testing.assert_array_equal(ar.tokens[:m], res.tokens[:m])


def test_reuse_and_approx_generate(nsa_pair):
    tp, tcfg, dp, dcfg = nsa_pair
    prompt = np.arange(24) % 128
    for pc, mode, sched in [("Reuse-only", "exact", (1,)),
                            ("Approx+Reuse", "approx", (1, 2))]:
        eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, ServeConfig(
            max_new_tokens=10, temperature=0.0, max_context=256,
            ssv=SSVConfig(tree_depth=2, tree_width=2, group_size=2,
                          group_mode=mode, refresh_schedule=sched,
                          precision_class=pc),
            use_planner=False))
        res = eng.generate(prompt, max_new_tokens=10)
        assert len(res.tokens) >= 10
        assert all(0 <= t < tcfg.vocab_size for t in res.tokens)


def test_dfs_traversal_and_stochastic(nsa_pair):
    tp, tcfg, dp, dcfg = nsa_pair
    prompt = np.arange(16) % 128
    eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, ServeConfig(
        max_new_tokens=8, temperature=0.7, max_context=256,
        ssv=SSVConfig(tree_depth=3, tree_width=2, traversal="dfs"),
        use_planner=False))
    res = eng.generate(prompt, max_new_tokens=8)
    assert len(res.tokens) >= 8


def test_recurrent_arch_speculation():
    """xLSTM (attention-free): verification via state replay must equal AR."""
    tcfg = ModelConfig(name="x", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=4, d_ff=0, vocab_size=64,
                       max_seq_len=512, dtype="float32",
                       block_pattern=("mlstm", "slstm"),
                       recurrent=RecurrentConfig(kind="mlstm", num_heads=4))
    dcfg = draft_lib.draft_config(tcfg, num_layers=1)
    tp = model.init(jax.random.PRNGKey(0), tcfg)
    dp = model.init(jax.random.PRNGKey(1), dcfg)
    prompt = np.arange(16) % 64
    ar = engine_lib.autoregressive_decode(tp, tcfg, prompt, 12, 256)
    eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, ServeConfig(
        max_new_tokens=12, temperature=0.0, max_context=256,
        ssv=SSVConfig(tree_depth=2, tree_width=2, precision_class="Strict"),
        use_planner=False))
    res = eng.generate(prompt, max_new_tokens=12)
    m = min(len(ar.tokens), len(res.tokens))
    np.testing.assert_array_equal(ar.tokens[:m], res.tokens[:m])


def test_trainer_restart_matches_uninterrupted(tmp_path):
    """Crash + restart must land on the same trajectory (deterministic data
    + checkpointed state)."""
    from repro.runtime.fault import FailureInjector, run_with_restarts
    from repro.runtime.trainer import Trainer
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")

    def run(ckdir, inject):
        tc = TrainConfig(steps=8, checkpoint_every=4, checkpoint_dir=ckdir,
                         learning_rate=1e-3, seed=3)
        inj = FailureInjector(fail_at_steps=[6]) if inject else None

        def driver():
            tr = Trainer(cfg, tc, batch_size=2, seq_len=32, injector=inj)
            tr.run()
            return tr

        if inject:
            holder = {}

            def d2():
                holder["tr"] = driver()
                return holder["tr"].state.step
            rep = run_with_restarts(d2)
            assert rep.completed and rep.restarts == 1
            return holder["tr"]
        return driver()

    tr_plain = run(str(tmp_path / "a"), inject=False)
    tr_crash = run(str(tmp_path / "b"), inject=True)
    la = jax.tree.leaves(tr_plain.state.params)
    lb = jax.tree.leaves(tr_crash.state.params)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
