"""NSA-semantics tests: selection properties (hypothesis), compression-cache
incremental consistency, refresh/reuse behavior, grouping approximation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container images without hypothesis: skip, don't error
    pytest.skip("hypothesis not installed", allow_module_level=True)

from repro.config import ModelConfig, NSAConfig, SSVConfig
from repro.models import model, nsa as nsa_lib

NSA = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4, window=32)
CFG = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=97, dtype="float32",
                  attention="nsa", nsa=NSA, max_seq_len=512)


@given(seed=st.integers(0, 100), prefix=st.integers(20, 120),
       depth=st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_selection_properties(seed, prefix, depth):
    rng = np.random.default_rng(seed)
    B, T, Hkv = 1, 3, 2
    nsb = nsa_lib.num_sel_blocks(128, NSA)
    p_slc = jnp.asarray(rng.random((B, T, Hkv, nsb)), jnp.float32)
    positions = jnp.asarray(prefix + np.arange(T) * max(depth, 1))[None]
    idx, valid = nsa_lib.select_topn(p_slc, positions, prefix, NSA)
    idx, valid = np.asarray(idx), np.asarray(valid)
    starts = np.arange(nsb) * NSA.sel_block
    for t in range(T):
        pos = prefix + t * max(depth, 1)
        for h in range(Hkv):
            sel = idx[0, t, h][valid[0, t, h]]
            # causality: selected blocks start within the committed prefix
            assert (starts[sel] < prefix).all()
            assert (starts[sel] <= pos).all()
            # sorted unique
            assert (np.diff(sel) > 0).all()
            # mandatory initial block present (if causal)
            if prefix > 0:
                assert 0 in sel
            # mandatory local block: block containing min(pos, prefix-1)
            lb = min(pos, prefix - 1) // NSA.sel_block
            assert lb in sel


def test_cmp_cache_incremental_matches_full():
    key = jax.random.PRNGKey(0)
    B, S, H, Dh = 2, 96, 2, CFG.head_dim
    k = jax.random.normal(key, (B, S, H, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, Dh))
    params = nsa_lib.nsa_init(jax.random.PRNGKey(2), CFG)
    full_k, full_v = nsa_lib.compress_kv(params, k, v, NSA)

    cache = {"k": k, "v": v}
    cmp_cache = nsa_lib.init_cmp_cache(CFG, B, S)
    # grow the prefix in uneven chunks, updating incrementally (dyn path)
    lens = [0, 17, 40, 41, 77, 96]
    for old, new in zip(lens[:-1], lens[1:]):
        cmp_cache = nsa_lib.update_cmp_cache_dyn(
            params, cache, cmp_cache, jnp.int32(old), jnp.int32(new),
            max_new=((new - old) // NSA.cmp_stride) + 2, nsa=NSA)
    ncb = nsa_lib.num_cmp_blocks(96, NSA)
    np.testing.assert_allclose(np.asarray(cmp_cache["k_cmp"][:, :ncb]),
                               np.asarray(full_k), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cmp_cache["v_cmp"][:, :ncb]),
                               np.asarray(full_v), rtol=1e-5, atol=1e-5)


def test_reuse_schedule_changes_output_but_bounded():
    """Reuse layers are a controlled approximation: different from
    all-refresh, but close (same model, same inputs)."""
    key = jax.random.PRNGKey(0)
    params = model.init(key, CFG)
    toks = jax.random.randint(key, (1, 80), 0, 97)
    _, caches = model.prefill(params, CFG, toks, max_len=160)
    T = 4
    positions = jnp.asarray(80 + np.array([0, 1, 1, 2]))[None]
    tm = np.zeros((T, T), bool)
    parents = [-1, 0, 0, 1]
    for i in range(T):
        j = i
        while j >= 0:
            tm[i, j] = True
            j = parents[j]
    tm = jnp.asarray(tm)[None]
    par = jnp.asarray(parents)
    dt = jax.random.randint(key, (1, T), 0, 97)

    lg_refresh, _ = model.verify_step(params, CFG, caches, dt, positions, tm, par,
                                      SSVConfig(refresh_schedule=()))
    lg_reuse, _ = model.verify_step(params, CFG, caches, dt, positions, tm, par,
                                    SSVConfig(refresh_schedule=(1,)))
    a = jax.nn.softmax(lg_refresh.astype(jnp.float32), -1)
    b = jax.nn.softmax(lg_reuse.astype(jnp.float32), -1)
    tv = 0.5 * float(jnp.abs(a - b).sum(-1).max())
    assert tv < 0.5  # close but...
    # layer-0 reuse request is ignored (mandatory refresh)
    lg_l0, _ = model.verify_step(params, CFG, caches, dt, positions, tm, par,
                                 SSVConfig(refresh_schedule=(0,)))
    np.testing.assert_allclose(np.asarray(lg_l0), np.asarray(lg_refresh),
                               rtol=1e-5, atol=1e-5)


def test_approx_grouping_controlled_approximation():
    key = jax.random.PRNGKey(0)
    params = model.init(key, CFG)
    toks = jax.random.randint(key, (1, 80), 0, 97)
    _, caches = model.prefill(params, CFG, toks, max_len=160)
    T = 6
    positions = jnp.asarray(80 + np.arange(T))[None]
    tm = jnp.asarray(np.tril(np.ones((T, T), bool)))[None]
    par = jnp.asarray([-1, 0, 1, 2, 3, 4])
    dt = jax.random.randint(key, (1, T), 0, 97)
    lg_exact, _ = model.verify_step(params, CFG, caches, dt, positions, tm, par,
                                    SSVConfig(group_mode="exact", group_size=2))
    lg_approx, _ = model.verify_step(params, CFG, caches, dt, positions, tm, par,
                                     SSVConfig(group_mode="approx", group_size=2))
    a = jax.nn.softmax(lg_exact.astype(jnp.float32), -1)
    b = jax.nn.softmax(lg_approx.astype(jnp.float32), -1)
    tv = 0.5 * float(jnp.abs(a - b).sum(-1).max())
    assert 0.0 <= tv < 0.6
    # exact grouping == no grouping (semantics preserved)
    lg_none, _ = model.verify_step(params, CFG, caches, dt, positions, tm, par,
                                   SSVConfig(group_mode="none", group_size=1))
    np.testing.assert_allclose(np.asarray(lg_exact), np.asarray(lg_none),
                               rtol=1e-4, atol=1e-4)


def test_overlap_profiling_positive():
    """Fig 2/4 reproduction at tiny scale: adjacent verifier queries have
    positive selected-block overlap (mandatory blocks guarantee > 0)."""
    from repro.core.overlap import adjacent_overlap
    key = jax.random.PRNGKey(0)
    params = model.init(key, CFG)
    toks = jax.random.randint(key, (1, 100), 0, 97)
    _, caches = model.prefill(params, CFG, toks, max_len=160)
    bp = jax.tree.map(lambda a: a[0], params["segments"][0][0])
    cache = jax.tree.map(lambda a: a[0], caches["segments"][0][0])
    T = 8
    positions = jnp.asarray(100 + np.arange(T))[None]
    x = jax.random.normal(key, (1, T, CFG.d_model))
    q, _, _ = __import__("repro.models.attention", fromlist=["qkv"]).qkv(
        bp["mix"], CFG, x, positions)
    _, p_slc = nsa_lib.routing(bp["mix"], CFG, q, cache["cmp"]["k_cmp"],
                               cache["cmp"]["v_cmp"], positions, kv_len=160,
                               ncb_valid=nsa_lib.num_cmp_blocks(100, NSA))
    idx, val = nsa_lib.select_topn(p_slc, positions, 100, NSA)
    r = np.asarray(adjacent_overlap(idx, val))
    assert (r > 0.2).all()  # mandatory init+local blocks force overlap
