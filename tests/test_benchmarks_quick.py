"""Benchmark-drift guard: every suite in benchmarks/run.py must import and
run to completion under --quick (CPU-sized shapes). A suite that breaks
against the current engine/model APIs fails tier-1 here instead of rotting
silently until the next full benchmark run."""
import json
import os
import sys

import numpy as np
import pytest

from benchmarks import run as bench_run

E2E_QUICK_JSON = "/tmp/BENCH_e2e.quick.json"


@pytest.mark.parametrize("name,modname", bench_run.SUITES,
                         ids=[n for n, _ in bench_run.SUITES])
def test_suite_quick(name, modname):
    bench_run.run_suite(modname, quick=True)


def test_e2e_quick_emits_continuous_serving_row():
    """The continuous-vs-drain serving benchmark must run under --quick and
    emit occupancy / queue-delay stats in the JSON report. Regenerates the
    report itself (never trusts a file another process / older checkout may
    have left at the fixed /tmp path)."""
    bench_run.run_suite("benchmarks.e2e_spec", quick=True)
    with open(E2E_QUICK_JSON) as f:
        report = json.load(f)
    cont = report["continuous"]
    for key in ("drain_tok_s", "continuous_tok_s", "speedup_vs_drain",
                "mean_occupancy", "mean_queue_delay_steps",
                "continuous_fused_steps", "drain_fused_steps"):
        assert key in cont, f"continuous serving row missing {key!r}"
    assert 0.0 < cont["mean_occupancy"] <= 1.0
    assert cont["mean_queue_delay_steps"] >= 0.0
    assert cont["continuous_tok_s"] > 0.0 and cont["drain_tok_s"] > 0.0
    # mid-flight admission never does MORE fused steps than drain-then-refill
    assert cont["continuous_fused_steps"] <= cont["drain_fused_steps"]


def test_runner_cli_quick_only_refinement(capsys):
    """The runner's --quick/--only plumbing itself (exit-on-failure path is
    covered by run_suite raising above)."""
    bench_run.main(["--quick", "--only", "refinement"])
    out = capsys.readouterr().out
    assert "refinement" in out and "done" in out
