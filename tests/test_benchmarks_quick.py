"""Benchmark-drift guard: every suite in benchmarks/run.py must import and
run to completion under --quick (CPU-sized shapes). A suite that breaks
against the current engine/model APIs fails tier-1 here instead of rotting
silently until the next full benchmark run."""
import sys

import numpy as np
import pytest

from benchmarks import run as bench_run


@pytest.mark.parametrize("name,modname", bench_run.SUITES,
                         ids=[n for n, _ in bench_run.SUITES])
def test_suite_quick(name, modname):
    bench_run.run_suite(modname, quick=True)


def test_runner_cli_quick_only_refinement(capsys):
    """The runner's --quick/--only plumbing itself (exit-on-failure path is
    covered by run_suite raising above)."""
    bench_run.main(["--quick", "--only", "refinement"])
    out = capsys.readouterr().out
    assert "refinement" in out and "done" in out
