"""Benchmark-drift guard: every suite in benchmarks/run.py must import and
run to completion under --quick (CPU-sized shapes). A suite that breaks
against the current engine/model APIs fails tier-1 here instead of rotting
silently until the next full benchmark run."""
import json
import os
import sys

import numpy as np
import pytest

from benchmarks import run as bench_run

E2E_QUICK_JSON = "/tmp/BENCH_e2e.quick.json"


@pytest.mark.parametrize("name,modname", bench_run.SUITES,
                         ids=[n for n, _ in bench_run.SUITES])
def test_suite_quick(name, modname):
    bench_run.run_suite(modname, quick=True)


def test_e2e_quick_emits_continuous_serving_row():
    """The continuous-vs-drain serving benchmark must run under --quick and
    emit occupancy / queue-delay stats in the JSON report. Regenerates the
    report itself (never trusts a file another process / older checkout may
    have left at the fixed /tmp path)."""
    bench_run.run_suite("benchmarks.e2e_spec", quick=True)
    with open(E2E_QUICK_JSON) as f:
        report = json.load(f)
    cont = report["continuous"]
    for key in ("drain_tok_s", "continuous_tok_s", "speedup_vs_drain",
                "mean_occupancy", "mean_queue_delay_steps",
                "continuous_fused_steps", "drain_fused_steps",
                "peak_kv_bytes"):
        assert key in cont, f"continuous serving row missing {key!r}"
    assert 0.0 < cont["mean_occupancy"] <= 1.0
    assert cont["mean_queue_delay_steps"] >= 0.0
    assert cont["continuous_tok_s"] > 0.0 and cont["drain_tok_s"] > 0.0
    # mid-flight admission never does MORE fused steps than drain-then-refill
    assert cont["continuous_fused_steps"] <= cont["drain_fused_steps"]
    # paged-vs-dense KV store row: lower peak KV bytes on the low-occupancy
    # workload, token-equal backends (the benchmark itself asserts equality)
    kv = report["kv_store"]
    for key in ("dense_peak_kv_bytes", "paged_peak_kv_bytes",
                "kv_bytes_ratio", "dense_tok_s", "paged_tok_s",
                "throughput_ratio", "mean_page_occupancy", "token_equal"):
        assert key in kv, f"kv_store row missing {key!r}"
    assert kv["token_equal"] is True
    assert kv["paged_peak_kv_bytes"] < kv["dense_peak_kv_bytes"]
    assert 0.0 < kv["kv_bytes_ratio"] < 1.0
    assert 0.0 <= kv["mean_page_occupancy"] <= 1.0
    # bucket-local vs shared-strategy mixed-length serving: execution groups
    # must be token-equal to single-stream generation under each row's
    # bucket strategy (the benchmark asserts it while the rows are in hand)
    # and must not lose aggregate accepted-token throughput to the one-
    # strategy-for-the-whole-batch baseline
    bk = report["bucketed"]
    for key in ("shared_tok_s", "bucketed_tok_s", "speedup_vs_shared",
                "group_launches", "bucket_occupancy", "step_cache",
                "token_equal", "n_short", "n_long"):
        assert key in bk, f"bucketed row missing {key!r}"
    assert bk["token_equal"] is True
    assert bk["bucketed_tok_s"] >= bk["shared_tok_s"], (
        f"bucket-local serving ({bk['bucketed_tok_s']:.1f} tok/s) fell below "
        f"the shared-strategy baseline ({bk['shared_tok_s']:.1f} tok/s)")
    # the run really partitioned the batch: both context buckets held slots
    assert len(bk["bucket_occupancy"]) >= 2
    assert bk["group_launches"] >= bk["bucketed_fused_steps"]
    # warmed AOT cache: every launch after warmup hit a compiled step
    assert bk["step_cache"]["step_cache_hits"] > 0


def test_runner_cli_quick_only_refinement(capsys):
    """The runner's --quick/--only plumbing itself (exit-on-failure path is
    covered by run_suite raising above)."""
    bench_run.main(["--quick", "--only", "refinement"])
    out = capsys.readouterr().out
    assert "refinement" in out and "done" in out


def test_runner_cli_only_accepts_comma_separated_list(capsys):
    """--only roofline,refinement runs BOTH suites (regression: the runner
    used to treat the whole string as one suite name and reject it)."""
    bench_run.main(["--quick", "--only", "roofline,refinement"])
    out = capsys.readouterr().out
    assert "# roofline done" in out and "# refinement done" in out


def test_runner_cli_only_unknown_name_lists_valid_suites(capsys):
    with pytest.raises(SystemExit):
        bench_run.main(["--quick", "--only", "e2e,nope"])
    err = capsys.readouterr().err
    assert "'nope'" in err
    for name, _ in bench_run.SUITES:
        assert name in err


def test_runner_cli_list_prints_suites_and_exits_zero(capsys):
    """``run.py --list`` prints every valid suite name (one per line) and
    returns success without importing or running any suite."""
    bench_run.main(["--list"])          # returning (no SystemExit) == exit 0
    out = capsys.readouterr().out
    assert out.splitlines() == [n for n, _ in bench_run.SUITES]
