"""Sequence-sharded NSA decode (shard_map split-KV) must match the
single-device reference — run in an 8-device subprocess."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_sharded_nsa_decode_matches_ref():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.config import ModelConfig, NSAConfig
        from repro.models import model, nsa as nsa_lib, nsa_sharded
        from repro.launch.mesh import make_test_mesh

        nsa = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4,
                        window=32)
        cfg = ModelConfig(name="t", num_layers=1, d_model=64, num_heads=4,
                          num_kv_heads=2, d_ff=128, vocab_size=97,
                          dtype="float32", attention="nsa", nsa=nsa)
        key = jax.random.PRNGKey(0)
        p = model.init(key, cfg)
        bp = jax.tree.map(lambda a: a[0], p["segments"][0][0])
        toks = jax.random.randint(key, (1, 200), 0, 97)
        # max_len divisible by 8 shards and by sel_block-unaligned on purpose
        _, caches = model.prefill(p, cfg, toks, max_len=264)
        cache = jax.tree.map(lambda a: a[0], caches["segments"][0][0])
        prefix = 200
        x = jax.random.normal(key, (1, 1, 64))
        positions = jnp.full((1, 1), prefix, jnp.int32)
        tm = jnp.ones((1, 1, 1), bool)

        # reference (single device)
        out_ref, (k_new, v_new), _ = nsa_lib.nsa_verify_ref(
            bp["mix"], cfg, x, cache["kv"], cache["cmp"], prefix, positions, tm)

        # sharded
        mesh = make_test_mesh(4, 2)
        seq_axes = ("data", "model")
        shard = NamedSharding(mesh, P(None, ("data", "model"), None, None))
        kv_s = {"k": jax.device_put(cache["kv"]["k"], shard),
                "v": jax.device_put(cache["kv"]["v"], shard)}
        # cmp cache padded (init_cmp_cache pads to 8-multiple at small scale)
        cmp_s = {"k_cmp": jax.device_put(cache["cmp"]["k_cmp"], shard),
                 "v_cmp": jax.device_put(cache["cmp"]["v_cmp"], shard)}
        with mesh:
            out_s, kv2, _ = nsa_sharded.nsa_attend_decode_sharded(
                bp["mix"], cfg, mesh, x, kv_s, cmp_s, jnp.int32(prefix),
                seq_axes)
        err = float(jnp.abs(out_ref.astype(jnp.float32) -
                            out_s.astype(jnp.float32)).max())
        scalemax = float(jnp.abs(out_ref).max())
        print("err", err, "scale", scalemax)
        assert err < 1e-3 * max(scalemax, 1.0), err
        # cache commit: new K written at position prefix
        got_k = np.asarray(kv2["k"][0, prefix])
        np.testing.assert_allclose(got_k, np.asarray(k_new[0, 0]),
                                   rtol=1e-5, atol=1e-6)
        print("SHARDED_NSA_OK")
    """)
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=420, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "SHARDED_NSA_OK" in p.stdout
