"""Acceptance-rule tests: greedy chain equivalence + stochastic exactness."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # container images without hypothesis: skip, don't error
    pytest.skip("hypothesis not installed", allow_module_level=True)

from repro.core.accept import (greedy_tree_accept, pad_path,
                               stochastic_tree_accept)
from repro.core.tree import build_topology, chain_topology


@given(seed=st.integers(0, 1000), depth=st.integers(1, 4), width=st.integers(1, 3),
       vocab=st.integers(4, 12))
@settings(max_examples=50, deadline=None)
def test_greedy_accept_invariants(seed, depth, width, vocab):
    rng = np.random.default_rng(seed)
    topo = build_topology(depth, width, "bfs")
    T = topo.num_nodes
    tokens = rng.integers(0, vocab, T)
    logits = rng.normal(size=(T, vocab))
    res = greedy_tree_accept(topo, tokens, logits)
    # path starts at root, is a valid parent chain
    assert res.path[0] == 0
    for a, b in zip(res.path[:-1], res.path[1:]):
        assert topo.parents[b] == a
    # every accepted draft token equals the argmax at its parent node
    for a, b in zip(res.path[:-1], res.path[1:]):
        assert tokens[b] == logits[a].argmax()
    # bonus = argmax at the deepest accepted node
    assert res.bonus == logits[res.path[-1]].argmax()
    assert res.n_accepted == len(res.path) - 1
    assert len(res.tokens) == res.n_accepted + 1


def test_greedy_equals_sequential_on_chain():
    """On a chain tree where the draft proposes exactly the argmax tokens,
    everything is accepted — speculative == sequential greedy."""
    rng = np.random.default_rng(1)
    V, gamma = 16, 5
    topo = chain_topology(gamma)
    logits = rng.normal(size=(topo.num_nodes, V))
    tokens = np.zeros(topo.num_nodes, np.int64)
    for i in range(1, topo.num_nodes):
        tokens[i] = logits[i - 1].argmax()
    res = greedy_tree_accept(topo, tokens, logits)
    assert res.n_accepted == gamma
    assert (res.tokens[:-1] == tokens[1:]).all()


def test_stochastic_preserves_target_distribution():
    """With gamma=1, the emitted first token must be distributed exactly as
    the target softmax regardless of the draft distribution q."""
    rng = np.random.default_rng(0)
    V = 5
    topo = chain_topology(1)
    t_logits = np.array([0.0, 1.0, 2.0, -1.0, 0.5])
    p = np.exp(t_logits - t_logits.max())
    p /= p.sum()
    q = np.array([0.5, 0.1, 0.1, 0.2, 0.1])
    counts = np.zeros(V)
    N = 4000
    for it in range(N):
        # draft proposes argmax-of-q deterministically here; vary via q-sample
        tok = rng.choice(V, p=q)
        tokens = np.array([0, tok])
        logits = np.stack([t_logits, t_logits])
        node_q = np.stack([q, q])
        res = stochastic_tree_accept(topo, tokens, logits, node_q, rng,
                                     temperature=1.0)
        counts[res.tokens[0]] += 1
    emp = counts / N
    assert np.abs(emp - p).max() < 0.05, (emp, p)


def test_pad_path():
    out = pad_path(np.array([0, 3, 7]), 5)
    assert out.tolist() == [0, 3, 7, 7, 7]
    out = pad_path(np.array([0]), 3)
    assert out.tolist() == [0, 0, 0]
