"""Continuous-batching invariant harness: mid-flight slot admission must
never perturb in-flight rows.

The core invariant is token equality — every request served under
continuous batching (random arrival orders, slot counts 1-4, rows admitted
into freed slots mid-generation) produces byte-identical tokens to the same
prompt run through single-stream ``SSVEngine.generate``. A seeded small case
runs in tier-1; the long randomized stress run is opt-in via ``--runslow``
(tests/conftest.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, NSAConfig, ServeConfig, SSVConfig
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.core import schedule as schedule_lib
from repro.models import model

NSA = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4, window=32)
MAX_NEW = 8
SSV = SSVConfig(tree_depth=2, tree_width=2)

PROMPTS = [np.arange(18) % 64, (np.arange(23) * 3) % 64,
           (np.arange(15) * 7) % 64, (np.arange(20) * 5) % 64,
           (np.arange(17) * 11) % 64, (np.arange(21) * 13) % 64]


def _serve(n=MAX_NEW, temperature=0.0, max_context=256):
    return ServeConfig(max_new_tokens=n, temperature=temperature,
                       max_context=max_context, ssv=SSV, use_planner=False)


@pytest.fixture(scope="module")
def ct_pair():
    tcfg = ModelConfig(name="ctgt", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=64,
                       max_seq_len=512, dtype="float32", attention="nsa",
                       nsa=NSA)
    dcfg = draft_lib.draft_config(tcfg, num_layers=1)
    tp = model.init(jax.random.PRNGKey(0), tcfg)
    dp = model.init(jax.random.PRNGKey(1), dcfg)
    return tp, tcfg, dp, dcfg


@pytest.fixture(scope="module")
def single_stream_reference(ct_pair):
    """Greedy single-stream output per prompt — the ground truth every
    continuous-batching configuration must reproduce exactly."""
    tp, tcfg, dp, dcfg = ct_pair
    ref = []
    for p in PROMPTS:
        eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve())
        ref.append(eng.generate(p, max_new_tokens=MAX_NEW).tokens)
    return ref


def _random_requests(seed, prompts=PROMPTS, max_arrival=6):
    """Random arrival order + times, deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(prompts))
    return [schedule_lib.Request(
                req_id=int(i), prompt=prompts[int(i)],
                arrival=float(rng.integers(0, max_arrival)))
            for i in order]


@pytest.mark.parametrize("slots", [1, 2, 3, 4])
def test_continuous_token_equality(ct_pair, single_stream_reference, slots):
    """Byte-identical tokens for every request, at every slot count, with
    arrival order decoupled from submission order."""
    tp, tcfg, dp, dcfg = ct_pair
    reqs = _random_requests(seed=slots)
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve())
    res = eng.serve_continuous(reqs, num_slots=slots, max_new_tokens=MAX_NEW)
    assert len(res.results) == len(PROMPTS)
    for req, gen in zip(res.requests, res.results):
        np.testing.assert_array_equal(
            single_stream_reference[req.req_id], gen.tokens,
            err_msg=f"request {req.req_id} diverged from single-stream "
                    f"(slots={slots}, admitted_at={req.admitted_at})")
    # the run really exercised MID-FLIGHT admission: with fewer slots than
    # requests, someone must have been admitted after the clock started
    if slots < len(PROMPTS):
        assert max(r.admitted_at for r in res.requests) > 0.0
    # everything was served and accounted
    assert all(r.finished_at is not None for r in res.requests)
    assert 0.0 < res.mean_occupancy <= 1.0
    assert res.steps == len(res.occupancy)


def test_admission_leaves_inflight_rows_untouched(ct_pair):
    """Direct cache-level check: admitting into slot 1 must not change a
    single byte of slot 0's KV rows, device length, or host mirrors."""
    tp, tcfg, dp, dcfg = ct_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve())
    eng.start_empty(2)
    eng.admit(0, PROMPTS[0])
    eng.step(active=np.array([True, False]))
    eng.step(active=np.array([True, False]))
    row0_before = [np.asarray(a[:, 0]).copy()
                   for a in jax.tree.leaves(eng.t_segs)]
    len_before = int(eng.committed_len[0])
    pending_before = int(eng.pending[0])
    eng.admit(1, PROMPTS[1])                  # mid-flight admission
    row0_after = [np.asarray(a[:, 0]) for a in jax.tree.leaves(eng.t_segs)]
    for b, a in zip(row0_before, row0_after):
        np.testing.assert_array_equal(b, a)
    assert int(eng.committed_len[0]) == len_before
    assert int(eng.pending[0]) == pending_before
    # and the next step advances both rows: the freshly-admitted one from its
    # reset length, the in-flight one from where it left off
    eng.step(active=np.array([True, True]))
    assert int(eng.committed_len[0]) > len_before
    assert int(eng.committed_len[1]) > len(PROMPTS[1]) - 1
    np.testing.assert_array_equal(np.asarray(eng.t_len), eng.committed_len)


def test_serve_continuous_rejects_bad_requests(ct_pair):
    tp, tcfg, dp, dcfg = ct_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve())
    with pytest.raises(ValueError, match="empty"):
        eng.serve_continuous([], num_slots=2)
    with pytest.raises(ValueError, match="max_context"):
        eng.serve_continuous([np.arange(300) % 64], num_slots=2)
    with pytest.raises(ValueError):
        eng.serve_continuous([PROMPTS[0]], num_slots=0)
    with pytest.raises(ValueError, match="req_id"):
        eng.serve_continuous(
            [schedule_lib.Request(req_id=0, prompt=PROMPTS[0]),
             schedule_lib.Request(req_id=0, prompt=PROMPTS[1])], num_slots=2)


def test_admit_validates_slot_and_prompt(ct_pair):
    tp, tcfg, dp, dcfg = ct_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve())
    eng.start_empty(2)
    with pytest.raises(ValueError, match="slot"):
        eng.admit(2, PROMPTS[0])
    with pytest.raises(ValueError, match="empty"):
        eng.admit(0, np.array([], np.int64))
    with pytest.raises(ValueError, match="max_context"):
        eng.admit(0, np.arange(257) % 64)


@pytest.mark.slow
@pytest.mark.parametrize("seed", [11, 29])
def test_continuous_stress_many_arrivals(ct_pair, seed):
    """Long-horizon randomized admission stress: more requests than slots,
    spread-out arrivals, mixed per-request budgets — every request still
    token-equal to single-stream generation."""
    tp, tcfg, dp, dcfg = ct_pair
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 64, size=int(rng.integers(12, 28)))
               for _ in range(10)]
    budgets = [int(rng.integers(4, 14)) for _ in prompts]
    ref = []
    for p, b in zip(prompts, budgets):
        eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve(n=b))
        ref.append(eng.generate(p, max_new_tokens=b).tokens)
    order = rng.permutation(len(prompts))
    reqs = [schedule_lib.Request(req_id=int(i), prompt=prompts[int(i)],
                                 max_new_tokens=budgets[int(i)],
                                 arrival=float(rng.integers(0, 20)))
            for i in order]
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve())
    res = eng.serve_continuous(reqs, num_slots=3)
    for req, gen in zip(res.requests, res.results):
        np.testing.assert_array_equal(ref[req.req_id], gen.tokens)
    assert max(r.admitted_at for r in res.requests) > 0.0
