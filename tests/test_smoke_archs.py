"""Per-architecture smoke tests: a REDUCED config of the same family runs one
forward/train step + a prefill/decode step on CPU; output shapes + no NaNs.
The FULL configs are exercised only by the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.config import TrainConfig
from repro.models import model
from repro.runtime.trainer import make_train_step
from repro.optim import adamw_init


@pytest.mark.parametrize("arch", cfglib.ASSIGNED)
def test_arch_smoke(arch):
    cfg = cfglib.reduced(arch)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    B, S = 2, 64
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend_dim:
        fe = jax.random.normal(key, (B, 8, cfg.frontend_dim))

    # one train step
    tcfg = TrainConfig(steps=1, learning_rate=1e-3)
    if fe is None:
        step = make_train_step(cfg, tcfg, donate=False)
        opt = adamw_init(params)
        p2, o2, _, metrics = step(params, opt, jnp.zeros(()), toks)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
    else:
        loss = model.loss_fn(params, cfg, toks, frontend=fe, remat=False)
        assert np.isfinite(float(loss))
        g = jax.grad(lambda p: model.loss_fn(p, cfg, toks, frontend=fe,
                                             remat=False))(params)
        gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
        assert np.isfinite(gn)

    # prefill + decode
    _, caches = model.prefill(params, cfg, toks, max_len=128, frontend=fe)
    logits, caches = model.decode_step(params, cfg, caches, toks[:, :1])
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ["qwen3-8b", "granite-20b"])
def test_nsa_variant_smoke(arch):
    """SSV serving mode: the arch with NSA attention swapped in."""
    cfg = cfglib.nsa_variant(cfglib.reduced(arch))
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    toks = jax.random.randint(key, (1, 48), 0, cfg.vocab_size)
    _, caches = model.prefill(params, cfg, toks, max_len=96)
    logits, caches = model.decode_step(params, cfg, caches, toks[:, :1])
    assert not bool(jnp.isnan(logits).any())


def test_full_config_params():
    """Full configs report plausible parameter counts (sanity of the
    analytic accounting the roofline uses)."""
    expect = {
        "nemotron-4-340b": (300e9, 380e9),
        "granite-20b": (15e9, 26e9),
        "qwen3-8b": (6e9, 10e9),
        "smollm-360m": (0.25e9, 0.5e9),
        "mixtral-8x22b": (120e9, 160e9),
        "recurrentgemma-9b": (7e9, 12e9),
        "xlstm-125m": (0.08e9, 0.2e9),
        "musicgen-medium": (1e9, 2.3e9),
        "pixtral-12b": (10e9, 15e9),
        "qwen3-moe-235b-a22b": (200e9, 270e9),
    }
    for arch, (lo, hi) in expect.items():
        n = cfglib.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
    # MoE active params strictly below total
    moe = cfglib.get_config("mixtral-8x22b")
    assert moe.active_param_count() < moe.param_count()
