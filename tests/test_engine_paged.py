"""Paged-vs-dense KV store backend parity for the serving engines.

The contract: `kv_backend="paged"` is a pure memory-layout change — every
request's tokens are byte-identical to the dense backend (and hence to
single-stream generation, which tests/test_engine_continuous.py pins to the
continuous dense path), across slot counts 1-4, mid-flight admissions, and a
page pool too small to hold every request at once (head-of-line waits).
Completion must return every page to the pool.
"""
import jax
import numpy as np
import pytest

from repro.config import ModelConfig, NSAConfig, ServeConfig, SSVConfig
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.core import schedule as schedule_lib
from repro.models import model

NSA = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4, window=32)
MAX_NEW = 8
SSV = SSVConfig(tree_depth=2, tree_width=2)

PROMPTS = [np.arange(18) % 64, (np.arange(23) * 3) % 64,
           (np.arange(15) * 7) % 64, (np.arange(20) * 5) % 64,
           (np.arange(17) * 11) % 64, (np.arange(21) * 13) % 64]


def _serve(backend="dense", temperature=0.0, **kw):
    return ServeConfig(max_new_tokens=MAX_NEW, temperature=temperature,
                       max_context=256, ssv=SSV, use_planner=False,
                       kv_backend=backend, **kw)


@pytest.fixture(scope="module")
def pg_pair():
    tcfg = ModelConfig(name="pgt", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=64,
                       max_seq_len=512, dtype="float32", attention="nsa",
                       nsa=NSA)
    dcfg = draft_lib.draft_config(tcfg, num_layers=1)
    tp = model.init(jax.random.PRNGKey(0), tcfg)
    dp = model.init(jax.random.PRNGKey(1), dcfg)
    return tp, tcfg, dp, dcfg


@pytest.fixture(scope="module")
def dense_reference(pg_pair):
    """Greedy dense single-stream output per prompt — what every paged
    configuration must reproduce exactly."""
    tp, tcfg, dp, dcfg = pg_pair
    ref = []
    for p in PROMPTS:
        eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve())
        ref.append(eng.generate(p, max_new_tokens=MAX_NEW).tokens)
    return ref


def test_single_stream_paged_equals_dense(pg_pair, dense_reference):
    tp, tcfg, dp, dcfg = pg_pair
    for i, p in enumerate(PROMPTS[:3]):
        eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, _serve("paged"))
        res = eng.generate(p, max_new_tokens=MAX_NEW)
        np.testing.assert_array_equal(dense_reference[i], res.tokens)
    # the paged single-stream engine really allocated a sub-max_context slice
    assert eng.allocator is not None
    assert eng.allocator.used_count < eng.allocator.num_pages


def _random_requests(seed, max_arrival=6):
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(PROMPTS))
    return [schedule_lib.Request(req_id=int(i), prompt=PROMPTS[int(i)],
                                 arrival=float(rng.integers(0, max_arrival)))
            for i in order]


@pytest.mark.parametrize("slots", [1, 2, 3, 4])
def test_continuous_paged_equals_dense(pg_pair, dense_reference, slots):
    """serve_continuous under the paged backend: byte-identical tokens per
    request at every slot count, rows admitted mid-flight included; all
    pages back in the pool afterwards."""
    tp, tcfg, dp, dcfg = pg_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve("paged"))
    res = eng.serve_continuous(_random_requests(seed=slots), num_slots=slots,
                               max_new_tokens=MAX_NEW)
    for req, gen in zip(res.requests, res.results):
        np.testing.assert_array_equal(
            dense_reference[req.req_id], gen.tokens,
            err_msg=f"request {req.req_id} diverged from dense "
                    f"(slots={slots}, admitted_at={req.admitted_at})")
    if slots < len(PROMPTS):
        assert max(r.admitted_at for r in res.requests) > 0.0  # mid-flight
    assert eng.allocator.free_count == eng.allocator.num_pages
    assert (eng.pages == -1).all()
    assert res.page_occupancy and 0.0 < max(res.page_occupancy) <= 1.0


def test_generate_batch_paged_equals_dense(pg_pair, dense_reference):
    tp, tcfg, dp, dcfg = pg_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve("paged"))
    res = eng.generate_batch(PROMPTS[:3], max_new_tokens=MAX_NEW)
    for i, r in enumerate(res.results):
        np.testing.assert_array_equal(dense_reference[i], r.tokens)


def test_constrained_pool_waits_for_pages_and_stays_token_equal(
        pg_pair, dense_reference):
    """A pool too small for all slots at once: admission must wait on page
    headroom (scheduler gate), never deadlock, and still serve every request
    token-identically. This is the regime where paged memory wins."""
    tp, tcfg, dp, dcfg = pg_pair
    serve = _serve("paged", kv_num_pages=8)       # each request needs ~3 pages
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, serve)
    reqs = [schedule_lib.Request(req_id=i, prompt=p)
            for i, p in enumerate(PROMPTS)]
    res = eng.serve_continuous(reqs, num_slots=3, max_new_tokens=MAX_NEW)
    for req, gen in zip(res.requests, res.results):
        np.testing.assert_array_equal(dense_reference[req.req_id], gen.tokens)
    assert eng.allocator.free_count == 8
    assert res.peak_page_occupancy <= 1.0
    # the footprint claim: 8 pages << 3 slots x 16 pages of dense layout
    dense_eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, _serve())
    dense_eng.start_empty(3)
    assert eng.kv_cache_bytes() < dense_eng.kv_cache_bytes() / 4


def test_paged_rejects_request_larger_than_pool(pg_pair):
    tp, tcfg, dp, dcfg = pg_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg,
                                      _serve("paged", kv_num_pages=2))
    with pytest.raises(ValueError, match="pages"):
        eng.serve_continuous([PROMPTS[0]], num_slots=1,
                             max_new_tokens=MAX_NEW)


def test_paged_stochastic_runs(pg_pair):
    """Temperature > 0 exercises the stochastic paged batched step."""
    tp, tcfg, dp, dcfg = pg_pair
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg,
                                      _serve("paged", temperature=0.7))
    res = eng.generate_batch([PROMPTS[0], PROMPTS[1]], max_new_tokens=6)
    for r in res.results:
        assert len(r.tokens) >= 6
        assert all(0 <= t < tcfg.vocab_size for t in r.tokens)


def test_released_slot_writes_cannot_corrupt_new_tenant(pg_pair,
                                                        dense_reference):
    """After a row finishes and its pages are freed, the (inactive but still
    vmapped) row's step output must not write into pages now owned by a
    newly admitted request: serve a workload engineered to recycle pages
    immediately and check the late requests' tokens."""
    tp, tcfg, dp, dcfg = pg_pair
    serve = _serve("paged", kv_num_pages=7)       # forces immediate reuse
    eng = engine_lib.BatchedSSVEngine(tp, tcfg, dp, dcfg, serve)
    reqs = [schedule_lib.Request(req_id=i, prompt=PROMPTS[i],
                                 arrival=float(i // 2))
            for i in range(len(PROMPTS))]
    res = eng.serve_continuous(reqs, num_slots=2, max_new_tokens=MAX_NEW)
    for req, gen in zip(res.requests, res.results):
        np.testing.assert_array_equal(dense_reference[req.req_id], gen.tokens)
