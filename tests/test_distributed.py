"""Multi-device behavior via subprocesses (the main test process must keep a
single CPU device — see conftest.py). Each case sets
XLA_FLAGS=--xla_force_host_platform_device_count=8 before importing jax."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    return p.stdout


def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.config import ModelConfig, TrainConfig
        from repro.models import model
        from repro.optim import adamw_init
        from repro.runtime.trainer import make_train_step
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_test_mesh

        cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, d_ff=128, vocab_size=128,
                          dtype="float32")
        tcfg = TrainConfig(steps=1, learning_rate=1e-3)
        params = model.init(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, 128)

        step1 = make_train_step(cfg, tcfg, donate=False)
        p1, o1, _, m1 = step1(params, opt, jnp.zeros(()), toks)

        mesh = make_test_mesh(2, 4)
        specs = shd.param_specs(cfg, params, mesh)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))
        params_s = jax.tree.map(jax.device_put, params, sh)
        opt_s = adamw_init(params_s)
        toks_s = jax.device_put(toks, NamedSharding(mesh, P(("data",), None)))
        with mesh:
            step2 = make_train_step(cfg, tcfg, donate=False)
            p2, o2, _, m2 = step2(params_s, opt_s, jnp.zeros(()), toks_s)
        print("loss1", float(m1["loss"]), "loss2", float(m2["loss"]))
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
        print("SHARDED_MATCH_OK")
    """)
    assert "SHARDED_MATCH_OK" in out


def test_elastic_reshard_checkpoint():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import save, restore
        from repro.runtime.elastic import plan_mesh, build_mesh

        # save on an 8-device (4,2) mesh
        m8 = build_mesh(plan_mesh(8, prefer_model=2))
        w = jnp.arange(64.0).reshape(8, 8)
        ws = jax.device_put(w, NamedSharding(m8, P("data", "model")))
        save("/tmp/repro_elastic_ck", 1, {"w": ws})

        # "lose" half the devices: restore onto a (2,2) mesh
        m4 = build_mesh(plan_mesh(4, prefer_model=2))
        tmpl = {"w": jnp.zeros((8, 8))}
        sh = {"w": NamedSharding(m4, P("data", "model"))}
        step, tree = restore("/tmp/repro_elastic_ck", tmpl, shardings=sh)
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(w))
        assert tree["w"].sharding.mesh.devices.size == 4
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_production_mesh_construction():
    out = run_sub("""
        import os
        # simulate the dry-run's 512-device environment at 8 devices by
        # checking the mesh helpers degrade correctly
        import jax
        from repro.runtime.elastic import plan_mesh
        mc = plan_mesh(8, prefer_model=4)
        assert mc.shape == (2, 4), mc.shape
        mc = plan_mesh(6, prefer_model=4)   # non-divisible: model shrinks
        assert mc.shape[0] * mc.shape[1] == 6
        mc = plan_mesh(8, prefer_model=2, multi_pod=True, pod_size=4)
        assert mc.axes == ("pod", "data", "model") and mc.shape == (2, 2, 2)
        print("MESH_OK")
    """)
    assert "MESH_OK" in out


def test_hlo_analyzer_trip_counts():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis import hlo
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(2, 4)
        L, D = 6, 512
        def f(x, Ws):
            y, _ = jax.lax.scan(lambda c, W: (jnp.tanh(c @ W), None), x, Ws)
            return y.sum()
        x = jax.ShapeDtypeStruct((256, D), jnp.bfloat16,
                                 sharding=NamedSharding(mesh, P("data", None)))
        Ws = jax.ShapeDtypeStruct((L, D, D), jnp.bfloat16,
                                  sharding=NamedSharding(mesh, P(None, "data", "model")))
        cp = jax.jit(f).lower(x, Ws).compile()
        a = hlo.analyze(cp.as_text(), num_devices=8)
        expected = L * 2 * (256 // 2) * D * (D // 4)   # per-device
        assert abs(a.flops / expected - 1) < 0.05, (a.flops, expected)
        assert a.collective_counts["all-gather"] > 0  # FSDP weight gathers
        assert a.total_wire_bytes > 0
        print("HLO_OK", a.flops, expected)
    """)
    assert "HLO_OK" in out
