"""End-to-end behaviour tests for the full SSV system: the draft-verify-
accept loop over a trained-ish model pair, planner integration, and the
serving CLI surface."""
import jax
import numpy as np
import pytest

from repro.config import (ModelConfig, NSAConfig, ServeConfig, SSVConfig)
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.core import planner as P
from repro.models import model

NSA = NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4, window=32)


@pytest.fixture(scope="module")
def system():
    tcfg = ModelConfig(name="sys", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=64,
                       max_seq_len=512, dtype="float32", attention="nsa",
                       nsa=NSA)
    dcfg = draft_lib.draft_config(tcfg, num_layers=1)
    tp = model.init(jax.random.PRNGKey(0), tcfg)
    dp = model.init(jax.random.PRNGKey(1), dcfg)
    return tp, tcfg, dp, dcfg


def test_generation_with_planner(system):
    tp, tcfg, dp, dcfg = system
    strategies = [SSVConfig(tree_depth=2, tree_width=2, precision_class="Strict"),
                  SSVConfig(tree_depth=3, tree_width=2, precision_class="Strict")]
    prof = P.Profile(table={(b, pc): [P.ProfileEntry(s, 2.0, 0.05)
                                      for s in strategies]
                            for b in range(4) for pc in P.PRECISION_CLASSES})
    planner = P.RuntimePlanner(prof, "Strict", warmup_m=2, hysteresis_h=2)
    eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, ServeConfig(
        max_new_tokens=12, temperature=0.0, max_context=256,
        ssv=strategies[0], use_planner=True), planner=planner)
    res = eng.generate(np.arange(16) % 64, max_new_tokens=12)
    assert len(res.tokens) >= 12
    # untrained pair -> low acceptance -> guard fires within the window
    assert planner.refinement_events >= 1
    assert planner.transitions <= P.MAX_TRANSITIONS


def test_all_precision_classes_generate(system):
    tp, tcfg, dp, dcfg = system
    for pc in P.PRECISION_CLASSES:
        mode, reuse = P.class_constraints(pc)
        ssv = SSVConfig(tree_depth=2, tree_width=2,
                        group_size=4 if mode == "approx" else 2,
                        group_mode=mode,
                        refresh_schedule=P.default_schedule(tcfg.num_layers)
                        if reuse else (),
                        precision_class=pc)
        eng = engine_lib.SSVEngine(tp, tcfg, dp, dcfg, ServeConfig(
            max_new_tokens=6, temperature=0.0, max_context=256, ssv=ssv,
            use_planner=False))
        res = eng.generate(np.arange(16) % 64, max_new_tokens=6)
        assert len(res.tokens) >= 6
        assert all(0 <= t < tcfg.vocab_size for t in res.tokens)
