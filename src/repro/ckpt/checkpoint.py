"""Checkpointing: atomic, async, mesh-free on disk, reshard-on-load.

Format: one ``.npz`` per checkpoint holding every leaf under its pytree path
plus a JSON sidecar (step, leaf manifest, user metadata). Writes go to a
temp directory that is atomically renamed — a crash mid-write never corrupts
the latest checkpoint. ``AsyncCheckpointer`` snapshots device arrays to host
(blocking only for the device->host copy) and writes in a background thread,
overlapping checkpoint I/O with subsequent training steps.

Arrays are stored *unsharded* (canonical layout); ``restore`` re-shards every
leaf onto the current mesh via the provided sharding tree — this is what
makes elastic restarts (different device count / mesh shape) work. At
production scale the same manifest supports per-shard files; the single-file
variant keeps CI hermetic.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


_NATIVE = {np.dtype(t) for t in
           ("float64", "float32", "float16", "int64", "int32", "int16", "int8",
            "uint64", "uint32", "uint16", "uint8", "bool")}


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype not in _NATIVE:  # bf16/fp8: store as f32 (lossless for
            arr = arr.astype(np.float32)  # bf16); restore casts to template dtype
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _unflatten(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs "
                             f"template {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(directory: str, step: int, tree, metadata: Optional[dict] = None):
    """Atomic synchronous save of ``tree`` at ``directory/step_<N>``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = {"step": step, "leaves": sorted(flat), "metadata": metadata or {},
            "time": time.time()}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, template, step: Optional[int] = None,
            shardings=None) -> Tuple[int, Any]:
    """Load the checkpoint at ``step`` (default: latest) into the structure of
    ``template``. If ``shardings`` (a pytree of jax.sharding.Sharding
    matching ``template``) is given, every leaf is device_put with it —
    re-sharding onto whatever mesh the caller is running now."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, t, s: jax.device_put(
                jax.numpy.asarray(a).astype(t.dtype), s),
            tree, template, shardings)
    else:
        tree = jax.tree.map(
            lambda a, t: jax.numpy.asarray(a).astype(t.dtype), tree, template)
    return step, tree


def gc_old(directory: str, keep: int = 3):
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host then write in a background thread. ``wait()`` blocks
    until the in-flight save lands (call before process exit / next save)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, metadata: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)  # blocking D2H

        def _write():
            try:
                save(self.directory, step, host_tree, metadata)
                gc_old(self.directory, self.keep)
            except BaseException as e:  # propagate on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
