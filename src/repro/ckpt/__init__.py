from repro.ckpt.checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    gc_old,
    latest_step,
    restore,
    save,
)
