"""Mixture-of-Experts FFN with top-k routing.

Two dispatch strategies (config/perf-selectable):

* ``dense_onehot`` (default): GShard-style grouped one-hot dispatch/combine
  einsums with a capacity factor. Fully pjit-native — XLA shards the dispatch
  einsums over (data × model) with no shard_map. Dispatch overhead is
  group_size·cf/(3·d_ff) of the expert FLOPs, so the group size is chosen per
  config (small d_ff archs like qwen3-moe use smaller groups).

* ``sorted_ep`` (optimization, see EXPERIMENTS.md §Perf): shard_map over the
  data axis, sort-based zero-FLOP dispatch into (E, C, d) with expert weights
  tensor-sharded over the model axis.

Both drop overflow tokens beyond capacity (standard GShard semantics) and add
the usual load-balancing auxiliary loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, MoEConfig
from repro.models import layers


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    moe = cfg.moe
    d = cfg.d_model
    dff = moe.d_expert or cfg.d_ff
    gated = cfg.activation in layers.GATED
    ks = jax.random.split(key, 5)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, moe.num_experts)) * 0.02).astype(jnp.float32),
        "w_up": (jax.random.normal(ks[1], (moe.num_experts, d, dff)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (moe.num_experts, dff, d)) / np.sqrt(dff)).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[3], (moe.num_experts, d, dff)) * scale).astype(dtype)
    if moe.num_shared_experts:
        p["shared"] = layers.ffn_init(ks[4], d, cfg.d_ff, cfg.activation, dtype)
    return p


def router_probs(params, x, moe: MoEConfig):
    """x: (N, d) -> (probs (N, E) f32, topk_idx (N, k), topk_w (N, k))."""
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, moe.top_k)
    topk_w = topk_w / jnp.clip(topk_w.sum(-1, keepdims=True), 1e-9)   # renormalize
    return probs, topk_idx, topk_w


def load_balance_loss(probs, topk_idx, num_experts: int):
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    N = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[topk_idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(1.0, N * topk_idx.shape[-1])
    p = probs.mean(axis=0)
    return num_experts * jnp.sum(f * p)


def _expert_ffn(params, xd, activation: str):
    """xd: (..., E, C, d) grouped tokens -> expert FFN output, batched over E."""
    if activation in layers.GATED:
        act = layers.GATED[activation]
        h = act(jnp.einsum("...ecd,edf->...ecf", xd, params["w_gate"])) * \
            jnp.einsum("...ecd,edf->...ecf", xd, params["w_up"])
    else:
        act = layers.ACTIVATIONS[activation]
        h = act(jnp.einsum("...ecd,edf->...ecf", xd, params["w_up"]))
    return jnp.einsum("...ecf,efd->...ecd", h, params["w_down"])


def moe_apply(params, cfg: ModelConfig, x, group_size: int = 0):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Grouped one-hot dispatch: tokens are reshaped to (n_groups, G, d); each
    group has capacity C = ceil(G * top_k * cf / E). Positions beyond capacity
    are dropped (their combine weight is 0; residual connection keeps the
    token's value).
    """
    moe = cfg.moe
    B, S, d = x.shape
    N = B * S
    xf = x.reshape(N, d)
    G = min(group_size or moe.dispatch_group, N)
    while N % G:
        G //= 2
    ngroups = N // G
    E, K = moe.num_experts, moe.top_k
    C = int(np.ceil(G * K * moe.capacity_factor / E))
    C = max(C, K)

    probs, topk_idx, topk_w = router_probs(params, xf, moe)
    aux = load_balance_loss(probs, topk_idx, E)

    xg = xf.reshape(ngroups, G, d)
    idx_g = topk_idx.reshape(ngroups, G, K)
    w_g = topk_w.reshape(ngroups, G, K)

    # position of each (token, k) within its expert, per group
    onehot = jax.nn.one_hot(idx_g, E, dtype=jnp.int32)                 # (n, G, K, E)
    flat = onehot.reshape(ngroups, G * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                              # (n, G*K, E)
    pos_in_e = (pos * flat).sum(-1).reshape(ngroups, G, K)             # (n, G, K)
    keep = pos_in_e < C
    w_g = jnp.where(keep, w_g, 0.0)

    # dispatch tensor (n, G, E, C) — bf16 one-hot keeps the einsum on the MXU
    disp = (jax.nn.one_hot(idx_g, E, dtype=x.dtype)[..., None] *
            jax.nn.one_hot(jnp.where(keep, pos_in_e, C), C + 1, dtype=x.dtype)[..., None, :]
            ).sum(axis=2)[..., :C]                                     # (n, G, E, C)
    xd = jnp.einsum("ngec,ngd->necd", disp, xg)                        # (n, E, C, d)
    yd = _expert_ffn(params, xd, cfg.activation)                       # (n, E, C, d)
    comb = (w_g[..., None, None].astype(jnp.float32) *
            jax.nn.one_hot(idx_g, E, dtype=jnp.float32)[..., None] *
            jax.nn.one_hot(jnp.where(keep, pos_in_e, C), C + 1,
                           dtype=jnp.float32)[..., None, :]).sum(axis=2)[..., :C]
    y = jnp.einsum("ngec,necd->ngd", comb.astype(x.dtype), yd)         # (n, G, d)
    y = y.reshape(B, S, d)
    if moe.num_shared_experts:
        y = y + layers.ffn(params["shared"], x, cfg.activation)
    return y, aux
