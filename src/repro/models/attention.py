"""Dense GQA attention: train (chunked causal), decode (KV cache), and
tree-masked speculative verification.

Shapes convention:
  x:        (B, S, D)
  q:        (B, S, Hq, Dh)
  k, v:     (B, S, Hkv, Dh)
  caches:   {"k": (B, S_max, Hkv, Dh), "v": ...}   (positions < length valid)

GQA is computed by reshaping q to (B, S, Hkv, G, Dh) where G = Hq // Hkv.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import layers

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.linear_init(ks[0], d, hq * hd, dtype)["w"],
        "wk": layers.linear_init(ks[1], d, hkv * hd, dtype)["w"],
        "wv": layers.linear_init(ks[2], d, hkv * hd, dtype)["w"],
        "wo": layers.linear_init(ks[3], hq * hd, d, dtype)["w"],
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.rmsnorm_init(hd, dtype)
        p["k_norm"] = layers.rmsnorm_init(hd, dtype)
    return p


def qkv(params, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (x @ params["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (x @ params["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = layers.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q: (B, Sq, Hkv, G, Dh); k/v: (B, Skv, Hkv, Dh); mask: (B|1, Sq, Skv) or
    (B|1, 1, 1, Sq, Skv) broadcastable.  Returns (B, Sq, Hkv, G, Dh)."""
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if mask is not None:
        if mask.ndim == 3:
            mask = mask[:, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def causal_mask(sq: int, skv: int, q_offset: int = 0, window: int = 0):
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None]  # (1, Sq, Skv)


def attend_train(params, cfg: ModelConfig, x, positions, window: int = 0,
                 chunk: int = 0, extra_mask=None, remat_chunks: bool = False):
    """Full-sequence causal attention (optionally sliding-window / masked).

    ``chunk`` > 0 scans over query chunks to bound the score working set —
    this is what keeps prefill_32k lowering memory-sane at full scale.
    ``extra_mask`` (B|1, Sq, Skv) is AND-ed in (used for NSA-selection
    train-mode masks and for tree masks).
    """
    B, S, _ = x.shape
    G = cfg.q_per_kv
    q, k, v = qkv(params, cfg, x, positions)
    qg = q.reshape(B, S, cfg.num_kv_heads, G, cfg.head_dim)
    scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)

    if chunk and S % chunk == 0 and S > chunk:
        nchunk = S // chunk
        qg_c = qg.reshape(B, nchunk, chunk, cfg.num_kv_heads, G, cfg.head_dim)

        def body(carry, inputs):
            i, qc = inputs
            m = causal_mask(chunk, S, q_offset=i * chunk, window=window)
            if extra_mask is not None:
                em = jax.lax.dynamic_slice_in_dim(extra_mask, i * chunk, chunk, axis=1)
                m = m & em
            o = _sdpa(qc, k, v, m, scale)
            return carry, o

        if remat_chunks:
            # remat per chunk: without this, backprop through the chunk scan
            # stores the full stacked (nchunk, ..., Sq_c, Skv) probability
            # residuals — the dominant HBM term in the train cells
            # (EXPERIMENTS.md §Perf iteration log)
            body = jax.checkpoint(body, prevent_cse=False)
        _, out = jax.lax.scan(body, None, (jnp.arange(nchunk), qg_c.swapaxes(0, 1)))
        out = out.swapaxes(0, 1).reshape(B, S, cfg.num_heads * cfg.head_dim)
    else:
        m = causal_mask(S, S, window=window)
        if extra_mask is not None:
            m = m & extra_mask
        out = _sdpa(qg, k, v, m, scale).reshape(B, S, cfg.num_heads * cfg.head_dim)
    return out @ params["wo"], (k, v)


def attend_train_online(params, cfg: ModelConfig, x, positions, window: int = 0,
                        q_chunk: int = 512, kv_chunk: int = 512):
    """Flash-style attention in pure XLA: online softmax over KV tiles, so
    the (Sq, Skv) score matrix is never materialized in HBM — the §Perf
    optimization for the memory-bound train/prefill cells (EXPERIMENTS.md).
    Backward is rematerialized per tile (inner checkpoint), flash-style.

    Semantics identical to ``attend_train`` (causal + optional window).
    """
    B, S, _ = x.shape
    Hkv, G, Dh = cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    q, k, v = qkv(params, cfg, x, positions)
    scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    while S % qc:
        qc //= 2
    while S % kc:
        kc //= 2
    nq, nk = S // qc, S // kc
    qg = q.reshape(B, nq, qc, Hkv, G, Dh)
    kt = k.reshape(B, nk, kc, Hkv, Dh)
    vt = v.reshape(B, nk, kc, Hkv, Dh)

    def q_block(qi):
        qx = qg[:, qi].astype(jnp.float32)                  # (B,qc,Hkv,G,Dh)
        qpos = qi * qc + jnp.arange(qc)

        def kv_step(carry, ki):
            m, l, acc = carry
            kx = kt[:, ki].astype(jnp.float32)
            vx = vt[:, ki].astype(jnp.float32)
            kpos = ki * kc + jnp.arange(kc)
            mask = kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qx, kx) * scale
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None]) * mask[None, None, None]
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vx)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, Hkv, G, qc), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, qc), jnp.float32),
                jnp.zeros((B, Hkv, G, qc, Dh), jnp.float32))
        # only KV tiles at or before this q chunk can be visible (causal)
        nk_needed = nk if window else nk  # static bound; masked anyway
        body = jax.checkpoint(kv_step, prevent_cse=False)
        (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nk_needed))
        o = jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None], 0.0)
        return o.transpose(0, 3, 1, 2, 4)                   # (B,qc,Hkv,G,Dh)

    _, outs = jax.lax.scan(lambda c, qi: (c, q_block(qi)), None, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = out.astype(x.dtype) @ params["wo"]
    return out, (k, v)


# ---------------------------------------------------------------- flash (custom_vjp)
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_core(q, k, v, scale, window, chunk):
    o, _ = _flash_fwd_impl(q, k, v, scale, window, chunk)
    return o


def _flash_fwd_impl(q, k, v, scale, window, chunk):
    """q: (B,S,Hkv,G,Dh) f32; k/v: (B,S,Hkv,Dh) f32. Returns (o, lse)."""
    B, S, Hkv, G, Dh = q.shape
    c = chunk
    nq = nk = S // c
    qt = q.reshape(B, nq, c, Hkv, G, Dh)
    kt = k.reshape(B, nk, c, Hkv, Dh)
    vt = v.reshape(B, nk, c, Hkv, Dh)

    def q_block(_, qi):
        qx = qt[:, qi]
        qpos = qi * c + jnp.arange(c)

        def kv_step(carry, ki):
            m, l, acc = carry
            kpos = ki * c + jnp.arange(c)
            mask = kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            lg = jnp.einsum("bqhgd,bkhd->bhgqk", qx, kt[:, ki]) * scale
            lg = jnp.where(mask[None, None, None], lg, NEG_INF)
            m_new = jnp.maximum(m, lg.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(lg - m_new[..., None]) * mask[None, None, None]
            return (m_new, l * alpha + p.sum(-1),
                    acc * alpha[..., None] +
                    jnp.einsum("bhgqk,bkhd->bhgqd", p, vt[:, ki])), None

        init = (jnp.full((B, Hkv, G, c), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, c), jnp.float32),
                jnp.zeros((B, Hkv, G, c, Dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        o = jnp.where(l[..., None] > 0, acc / jnp.maximum(l, 1e-30)[..., None], 0.0)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (o.transpose(0, 3, 1, 2, 4), lse)   # (B,c,Hkv,G,Dh)

    _, (outs, lses) = jax.lax.scan(q_block, None, jnp.arange(nq))
    o = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Hkv, G, Dh)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, S)
    return o, lse


def _flash_fwd(q, k, v, scale, window, chunk):
    o, lse = _flash_fwd_impl(q, k, v, scale, window, chunk)
    return o, (q, k, v, o, lse)


def _flash_bwd(scale, window, chunk, res, do):
    """FlashAttention-style backward: recompute p tiles from saved lse; two
    passes (dq over q chunks; dk/dv over kv chunks). Because this runs inside
    custom_vjp, the scans are primal-only — no per-step carry residuals are
    stored (the traffic/memory failure mode of naive autodiff through online
    softmax; see EXPERIMENTS.md §Perf iteration log)."""
    q, k, v, o, lse = res
    B, S, Hkv, G, Dh = q.shape
    c = chunk
    n = S // c
    qt = q.reshape(B, n, c, Hkv, G, Dh)
    kt = k.reshape(B, n, c, Hkv, Dh)
    vt = v.reshape(B, n, c, Hkv, Dh)
    dot = do.reshape(B, n, c, Hkv, G, Dh)
    lset = lse.reshape(B, Hkv, G, n, c)
    D = jnp.einsum("bshgd,bshgd->bhgs", do, o).reshape(B, Hkv, G, n, c)

    def mask_of(qi, ki, qpos, kpos):
        m = kpos[None, :] <= qpos[:, None]
        if window > 0:
            m &= kpos[None, :] > qpos[:, None] - window
        return m

    def dq_block(_, qi):
        qx, dox = qt[:, qi], dot[:, qi]
        lsei, Di = lset[:, :, :, qi], D[:, :, :, qi]
        qpos = qi * c + jnp.arange(c)

        def kv_step(dq, ki):
            kpos = ki * c + jnp.arange(c)
            m = mask_of(qi, ki, qpos, kpos)
            lg = jnp.einsum("bqhgd,bkhd->bhgqk", qx, kt[:, ki]) * scale
            p = jnp.exp(lg - lsei[..., None]) * m[None, None, None]
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dox, vt[:, ki])
            ds = p * (dp - Di[..., None]) * scale
            return dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kt[:, ki]), None

        dq, _ = jax.lax.scan(kv_step, jnp.zeros_like(qx), jnp.arange(n))
        return None, dq

    _, dqs = jax.lax.scan(dq_block, None, jnp.arange(n))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Hkv, G, Dh)

    def dkv_block(_, ki):
        kx, vx = kt[:, ki], vt[:, ki]
        kpos = ki * c + jnp.arange(c)

        def q_step(carry, qi):
            dk, dv = carry
            qpos = qi * c + jnp.arange(c)
            m = mask_of(qi, ki, qpos, kpos)
            qx, dox = qt[:, qi], dot[:, qi]
            lg = jnp.einsum("bqhgd,bkhd->bhgqk", qx, kx) * scale
            p = jnp.exp(lg - lset[:, :, :, qi][..., None]) * m[None, None, None]
            dv = dv + jnp.einsum("bhgqk,bqhgd->bkhd", p, dox)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dox, vx)
            ds = p * (dp - D[:, :, :, qi][..., None]) * scale
            dk = dk + jnp.einsum("bhgqk,bqhgd->bkhd", ds, qx)
            return (dk, dv), None

        (dk, dv), _ = jax.lax.scan(q_step, (jnp.zeros_like(kx), jnp.zeros_like(vx)),
                                   jnp.arange(n))
        return None, (dk, dv)

    _, (dks, dvs) = jax.lax.scan(dkv_block, None, jnp.arange(n))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, S, Hkv, Dh)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, S, Hkv, Dh)
    return dq, dk, dv


_flash_core.defvjp(_flash_fwd, _flash_bwd)


def attend_train_flash(params, cfg: ModelConfig, x, positions, window: int = 0,
                       chunk: int = 512):
    """Flash attention with a FlashAttention-style custom VJP — the §Perf
    memory-term optimization for train/prefill: neither forward nor backward
    materializes (Sq, Skv) scores or per-tile softmax carries in HBM."""
    B, S, _ = x.shape
    Hkv, G, Dh = cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    q, k, v = qkv(params, cfg, x, positions)
    c = min(chunk, S)
    while S % c:
        c //= 2
    scale = float(1.0 / np.sqrt(Dh))
    o = _flash_core(q.reshape(B, S, Hkv, G, Dh).astype(jnp.float32),
                    k.astype(jnp.float32), v.astype(jnp.float32),
                    scale, window, c)
    out = o.reshape(B, S, cfg.num_heads * cfg.head_dim).astype(x.dtype)
    return out @ params["wo"], (k, v)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def write_cache(cache, k_new, v_new, start):
    """Insert (B, T, Hkv, Dh) at position ``start`` (scalar or per-batch)."""
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), start, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), start, axis=1)
    return {"k": k, "v": v}


def attend_decode(params, cfg: ModelConfig, x, cache, length, window: int = 0):
    """Single-step decode: x (B, 1, D); attends over cache[:length] + itself.

    Returns (out (B,1,D), updated cache). ``length`` is the number of valid
    tokens already in the cache (the new token is written at ``length``).
    Sliding-window attention slices only the trailing window of the cache,
    keeping decode cost O(window) — this is what makes the hybrid archs'
    long-context decode sub-quadratic.
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), length, jnp.int32)
    q, k_new, v_new = qkv(params, cfg, x, positions)
    cache = write_cache(cache, k_new, v_new, length)
    S_max = cache["k"].shape[1]
    G = cfg.q_per_kv
    qg = q.reshape(B, 1, cfg.num_kv_heads, G, cfg.head_dim)
    scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)
    if window > 0 and S_max > window:
        W = window + 1  # include the token just written
        start = jnp.clip(length - window, 0, S_max - W)
        k_w = jax.lax.dynamic_slice_in_dim(cache["k"], start, W, axis=1)
        v_w = jax.lax.dynamic_slice_in_dim(cache["v"], start, W, axis=1)
        kpos = (start + jnp.arange(W))[None, None, :]
        mask = (kpos <= length) & (kpos > length - window)
        out = _sdpa(qg, k_w, v_w, mask, scale)
    else:
        kpos = jnp.arange(S_max)[None, None, :]
        mask = kpos <= length
        if window > 0:
            mask &= kpos > length - window
        out = _sdpa(qg, cache["k"], cache["v"], mask, scale)
    out = out.reshape(B, 1, cfg.num_heads * cfg.head_dim) @ params["wo"]
    return out, cache


def attend_verify(params, cfg: ModelConfig, x, cache, prefix_len, positions,
                  tree_mask, window: int = 0):
    """Tree-masked verification over gamma draft tokens (dense baseline).

    x: (B, T, D) draft-token hidden states (flattened tree, any traversal)
    positions: (B, T) absolute positions of each draft token
    tree_mask: (B, T, T) bool — draft token i may attend draft token j
    The draft K/V are appended *temporarily* (cache unchanged on return);
    acceptance decides what is committed via ``write_cache``.

    ``cache`` is a raw ``{"k", "v"}`` dict or a ``kvstore.KVView``. Dense
    attention reads the whole prefix, so a paged view is materialized to its
    logical (B, S, Hkv, Dh) layout here (page gather; unmapped pages read
    zeros and are masked by ``prefix_len`` like any garbage past the
    prefix) — paging pays off in the NSA branches, not this dense baseline.
    """
    if isinstance(cache, dict):
        cache_k, cache_v = cache["k"], cache["v"]
    else:                       # kvstore.KVView (duck-typed: no import cycle)
        cache_k, cache_v = cache.full()
    B, T, _ = x.shape
    q, k_new, v_new = qkv(params, cfg, x, positions)
    G = cfg.q_per_kv
    qg = q.reshape(B, T, cfg.num_kv_heads, G, cfg.head_dim)
    scale = 1.0 / jnp.sqrt(cfg.head_dim).astype(jnp.float32)

    S_max = cache_k.shape[1]
    kpos = jnp.arange(S_max)[None, None, :]
    prefix_mask = kpos < prefix_len[..., None, None] if hasattr(prefix_len, "ndim") and getattr(prefix_len, "ndim", 0) > 0 \
        else kpos < prefix_len
    prefix_mask = jnp.broadcast_to(prefix_mask, (B, T, S_max))
    if window > 0:
        prefix_mask &= kpos > positions[..., None] - window

    logits_p = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                          cache_k.astype(jnp.float32)) * scale
    logits_p = jnp.where(prefix_mask[:, None, None], logits_p, NEG_INF)
    logits_d = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                          k_new.astype(jnp.float32)) * scale
    dmask = tree_mask
    if window > 0:
        dist = positions[:, :, None] - positions[:, None, :]
        dmask = dmask & (dist < window)
    logits_d = jnp.where(dmask[:, None, None], logits_d, NEG_INF)

    logits = jnp.concatenate([logits_p, logits_d], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    pp, pd = probs[..., :S_max], probs[..., S_max:]
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pp, cache_v.astype(jnp.float32)) \
        + jnp.einsum("bhgqk,bkhd->bqhgd", pd, v_new.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, T, cfg.num_heads * cfg.head_dim) @ params["wo"]
    return out, (k_new, v_new)
