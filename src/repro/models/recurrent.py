"""Recurrent sequence-mixing blocks: RG-LRU (Griffin / RecurrentGemma) and
xLSTM (sLSTM + mLSTM).

These are the attention-free architectures in the assigned pool. The NSA/SSV
selection machinery is inapplicable here (no KV cache to route over —
see DESIGN.md §Arch-applicability); speculative verification is still
supported via *state replay*: draft-tree tokens are stepped through the
recurrence in topological order with per-node state snapshots
(``verify_states``), so accept/reject semantics match the attention path.

Train mode uses an associative scan for RG-LRU (linear recurrence) and a
sequential ``lax.scan`` for the xLSTM cells (which have nonlinear/normalized
state updates).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import layers

RGLRU_C = 8.0  # Griffin's fixed exponent scale


# =================================================================== RG-LRU
def rglru_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    sd = (cfg.recurrent.state_dim or d) if cfg.recurrent else d
    cw = cfg.recurrent.conv_width if cfg.recurrent else 4
    ks = jax.random.split(key, 6)
    # Lambda init so a = sigmoid(lam)^c in (0.9, 0.999) (Griffin appendix)
    u = jax.random.uniform(ks[0], (sd,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / RGLRU_C) / (1 - u ** (1.0 / RGLRU_C)))
    return {
        "w_in": layers.linear_init(ks[1], d, sd, dtype)["w"],
        "w_gate_branch": layers.linear_init(ks[2], d, sd, dtype)["w"],
        "conv": (jax.random.normal(ks[3], (cw, sd)) * 0.02).astype(dtype),
        "w_a": layers.linear_init(ks[4], sd, sd, dtype)["w"],   # recurrence gate
        "w_x": layers.linear_init(ks[5], sd, sd, dtype)["w"],   # input gate
        "lam": lam,
        "w_out": layers.linear_init(jax.random.fold_in(key, 7), sd, d, dtype)["w"],
    }


def _causal_conv(conv_w, x, state=None):
    """Depthwise causal conv. x: (B, S, sd); conv_w: (cw, sd).
    state: (B, cw-1, sd) trailing inputs from previous call (decode)."""
    cw = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                       # (B, S+cw-1, sd)
    out = sum(xp[:, i : i + x.shape[1]] * conv_w[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return out, new_state


def _rglru_coeffs(params, u):
    """u: (..., sd) conv output -> (a, b) of h_t = a*h_{t-1} + b."""
    r = jax.nn.sigmoid(u.astype(jnp.float32) @ params["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(u.astype(jnp.float32) @ params["w_x"].astype(jnp.float32))
    log_a = -RGLRU_C * r * jax.nn.softplus(-params["lam"])       # log sigmoid(lam)^(c*r)
    a = jnp.exp(log_a)
    gated = i * u.astype(jnp.float32)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * gated
    return a, b


def rglru_apply_train(params, cfg: ModelConfig, x):
    """x: (B, S, d) -> (B, S, d), via associative scan over the sequence."""
    u0 = x @ params["w_in"]
    gate = jax.nn.gelu(x @ params["w_gate_branch"])
    u, _ = _causal_conv(params["conv"], u0)
    a, b = _rglru_coeffs(params, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = (hh * gate.astype(jnp.float32)).astype(x.dtype)
    return h @ params["w_out"]


def rglru_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    sd = (cfg.recurrent.state_dim or cfg.d_model) if cfg.recurrent else cfg.d_model
    cw = cfg.recurrent.conv_width if cfg.recurrent else 4
    return {"h": jnp.zeros((batch, sd), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, sd), dtype)}


def rglru_step(params, cfg: ModelConfig, x, state):
    """x: (B, 1, d); state from rglru_init_state. Returns (out (B,1,d), state)."""
    u0 = x @ params["w_in"]
    gate = jax.nn.gelu(x @ params["w_gate_branch"])
    u, conv_state = _causal_conv(params["conv"], u0, state["conv"])
    a, b = _rglru_coeffs(params, u)
    h = a[:, 0] * state["h"] + b[:, 0]
    out = (h[:, None] * gate.astype(jnp.float32)).astype(x.dtype) @ params["w_out"]
    return out, {"h": h, "conv": conv_state}


# =================================================================== mLSTM
def mlstm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.recurrent.num_heads or cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 7)
    return {
        "wq": layers.linear_init(ks[0], d, d, dtype)["w"],
        "wk": layers.linear_init(ks[1], d, d, dtype)["w"],
        "wv": layers.linear_init(ks[2], d, d, dtype)["w"],
        "wi": (jax.random.normal(ks[3], (d, H)) * 0.02).astype(jnp.float32),
        "wf": (jax.random.normal(ks[4], (d, H)) * 0.02).astype(jnp.float32),
        "bf": jnp.full((H,), 3.0, jnp.float32),  # forget-gate bias: remember by default
        "wo_gate": layers.linear_init(ks[5], d, d, dtype)["w"],
        "w_out": layers.linear_init(ks[6], d, d, dtype)["w"],
    }


def mlstm_init_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    H = cfg.recurrent.num_heads or cfg.num_heads
    dh = d // H
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def _mlstm_qkvif(params, cfg, x):
    d = cfg.d_model
    H = cfg.recurrent.num_heads if (cfg.recurrent and cfg.recurrent.num_heads) else cfg.num_heads
    dh = d // H
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, H, dh).astype(jnp.float32) / np.sqrt(dh)
    k = (x @ params["wk"]).reshape(B, S, H, dh).astype(jnp.float32) / np.sqrt(dh)
    v = (x @ params["wv"]).reshape(B, S, H, dh).astype(jnp.float32)
    it = x.astype(jnp.float32) @ params["wi"]                       # log input gate
    ft = x.astype(jnp.float32) @ params["wf"] + params["bf"]        # pre-sigmoid forget
    return q, k, v, it, ft


def mlstm_step_state(state, qkvif):
    """One stabilized mLSTM step. qkvif at one time index: q,k,v (B,H,dh), it,ft (B,H)."""
    q, k, v, it, ft = qkvif
    logf = -jax.nn.softplus(-ft)                                    # log sigmoid(ft)
    m_new = jnp.maximum(logf + state["m"], it)
    fg = jnp.exp(logf + state["m"] - m_new)
    ig = jnp.exp(it - m_new)
    C = fg[..., None, None] * state["C"] + ig[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = fg[..., None] * state["n"] + ig[..., None] * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), 1.0)
    h = num / den[..., None]
    return {"C": C, "n": n, "m": m_new}, h


def _chunked_time_scan(body, state, S: int, chunk: int = 256):
    """Sequential time scan rematerialized per chunk: backward stores only
    chunk-boundary states instead of every step's state — what keeps the
    xLSTM 4K-token training cells inside HBM (see EXPERIMENTS.md §Dry-run)."""
    if S <= chunk or S % chunk:
        return jax.lax.scan(body, state, jnp.arange(S))

    def chunk_body(st, ts):
        return jax.lax.scan(body, st, ts)

    chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)
    st, hs = jax.lax.scan(chunk_body, state,
                          jnp.arange(S).reshape(S // chunk, chunk))
    return st, hs.reshape((S,) + hs.shape[2:])


def mlstm_apply_train(params, cfg: ModelConfig, x):
    B, S, d = x.shape
    q, k, v, it, ft = _mlstm_qkvif(params, cfg, x)
    state = mlstm_init_state(cfg, B)

    def body(st, t):
        st, h = mlstm_step_state(st, (q[:, t], k[:, t], v[:, t], it[:, t], ft[:, t]))
        return st, h

    _, hs = _chunked_time_scan(body, state, S)
    hs = hs.swapaxes(0, 1).reshape(B, S, d)                        # (B,S,H,dh)->(B,S,d)
    o = jax.nn.sigmoid((x @ params["wo_gate"]).astype(jnp.float32))
    return (hs * o).astype(x.dtype) @ params["w_out"]


def mlstm_step(params, cfg: ModelConfig, x, state):
    """x: (B, 1, d)."""
    q, k, v, it, ft = _mlstm_qkvif(params, cfg, x)
    state, h = mlstm_step_state(state, (q[:, 0], k[:, 0], v[:, 0], it[:, 0], ft[:, 0]))
    B, d = x.shape[0], x.shape[2]
    o = jax.nn.sigmoid((x[:, 0] @ params["wo_gate"]).astype(jnp.float32))
    out = ((h.reshape(B, d) * o).astype(x.dtype) @ params["w_out"])[:, None]
    return out, state


# =================================================================== sLSTM
def slstm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    # z, i, f, o projections fused: (d, 4d) input + (d, 4d) recurrent
    return {
        "w_x": (jax.random.normal(ks[0], (d, 4 * d)) / np.sqrt(d)).astype(dtype),
        "w_h": (jax.random.normal(ks[1], (d, 4 * d)) * 0.02).astype(jnp.float32),
        "b": jnp.concatenate([jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))]),
        "w_out": layers.linear_init(ks[2], d, d, dtype)["w"],
    }


def slstm_init_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32), "n": jnp.ones((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32), "m": jnp.zeros((batch, d), jnp.float32)}


def slstm_step_state(params, state, xt):
    """xt: (B, d) one timestep."""
    d = xt.shape[-1]
    pre = xt.astype(jnp.float32) @ params["w_x"].astype(jnp.float32) + \
        state["h"] @ params["w_h"] + params["b"]
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    logf = -jax.nn.softplus(-f)
    m_new = jnp.maximum(logf + state["m"], i)
    ig = jnp.exp(i - m_new)
    fg = jnp.exp(logf + state["m"] - m_new)
    c = fg * state["c"] + ig * jnp.tanh(z)
    n = fg * state["n"] + ig
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}, h


def slstm_apply_train(params, cfg: ModelConfig, x):
    B, S, d = x.shape
    state = slstm_init_state(cfg, B)

    def body(st, t):
        st, h = slstm_step_state(params, st, x[:, t])
        return st, h

    _, hs = _chunked_time_scan(body, state, S)
    return hs.swapaxes(0, 1).astype(x.dtype) @ params["w_out"]


def slstm_step(params, cfg: ModelConfig, x, state):
    state, h = slstm_step_state(params, state, x[:, 0])
    return (h[:, None].astype(x.dtype) @ params["w_out"]), state


# ================================================= recurrent kind dispatch
INITS = {"rglru": rglru_init, "mlstm": mlstm_init, "slstm": slstm_init}
TRAIN = {"rglru": rglru_apply_train, "mlstm": mlstm_apply_train, "slstm": slstm_apply_train}
STEPS = {"rglru": rglru_step, "mlstm": mlstm_step, "slstm": slstm_step}
STATE_INITS = {"rglru": rglru_init_state, "mlstm": mlstm_init_state, "slstm": slstm_init_state}


def verify_states(step_fn, params, cfg: ModelConfig, x, parents, state):
    """Tree-verify through a recurrence: process flattened draft tokens in
    topological order; node i consumes its parent's state (parent < i, root
    parent = -1 meaning the committed state).

    x: (B, T, d); parents: (T,) int32. Returns (outs (B, T, d),
    states list-like pytree with leading (T+1) node axis where slot 0 is the
    committed state and slot i+1 is node i's post-state).
    """
    B, T, d = x.shape
    buf = jax.tree.map(lambda s: jnp.broadcast_to(s[None], (T + 1,) + s.shape), state)
    buf = jax.tree.map(lambda s: s.astype(jnp.float32), buf)

    def body(buf, i):
        parent_state = jax.tree.map(lambda s: s[parents[i] + 1], buf)
        xi = jax.lax.dynamic_slice_in_dim(x, i, 1, axis=1)
        out, new_state = step_fn(params, cfg, xi, parent_state)
        buf = jax.tree.map(lambda b, ns: b.at[i + 1].set(ns.astype(b.dtype)), buf, new_state)
        return buf, out[:, 0]

    buf, outs = jax.lax.scan(body, buf, jnp.arange(T))
    return outs.swapaxes(0, 1), buf
