"""Composable decoder-only model covering all assigned architectures.

Layers are organized into *segments*: maximal runs of the repeating
``block_pattern`` that can be scanned with stacked parameters (compile time
O(1) in depth — essential for the 96-layer dry-run cells). A segment holds a
tuple of stacked block-param trees, one per position in the pattern group.

Four execution paths:
  * ``loss_fn`` / ``forward_train`` — full-sequence causal training forward
    (chunked attention + chunked vocab cross-entropy).
  * ``prefill``     — training-style forward that also builds KV / compressed /
    recurrent caches for serving.
  * ``decode_step`` — single-token autoregressive decode (the paper's NSA
    decode baseline when ``cfg.attention == "nsa"``).
  * ``verify_step`` — gamma tree-masked draft tokens; NSA layers implement the
    paper's refresh/reuse schedule (cross-layer index inheritance via the
    layer-scan carry + ``lax.cond``) and exact/approx grouped selection via
    externally transformed indices.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, SSVConfig
from repro.core import kvstore
from repro.models import attention, layers, moe as moe_lib, nsa as nsa_lib, recurrent

RECURRENT_KINDS = ("rglru", "mlstm", "slstm")


# ------------------------------------------------------------------ segments
def segments(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """[(group kinds, n_groups)] — tiles block_pattern over num_layers."""
    pat = tuple(cfg.block_pattern)
    m = len(pat)
    full = cfg.num_layers // m
    segs: List[Tuple[Tuple[str, ...], int]] = []
    if full > 0:
        segs.append((pat, full))
    rem = cfg.num_layers - full * m
    if rem:
        segs.append((tuple(cfg.layer_kinds()[full * m:]), 1))
    return segs


def layer_index(cfg: ModelConfig, seg_idx: int, group_idx, pos_in_group: int):
    """Absolute layer index of (segment, group, position)."""
    segs = segments(cfg)
    base = sum(len(k) * n for k, n in segs[:seg_idx])
    return base + group_idx * len(segs[seg_idx][0]) + pos_in_group


# ------------------------------------------------------------------ blocks
def block_init(key, cfg: ModelConfig, kind: str, dtype):
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {
        "norm1": layers.rmsnorm_init(cfg.d_model, dtype),
        "norm2": layers.rmsnorm_init(cfg.d_model, dtype),
    }
    if kind in RECURRENT_KINDS:
        p["mix"] = recurrent.INITS[kind](k1, cfg, dtype)
        if cfg.d_ff:
            p["ffn"] = layers.ffn_init(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
        return p
    if cfg.attention == "nsa":
        p["mix"] = nsa_lib.nsa_init(k1, cfg, dtype)
    else:
        p["mix"] = attention.attn_init(k1, cfg, dtype)
    if kind == "moe":
        p["ffn"] = moe_lib.moe_init(k2, cfg, dtype)
    else:
        p["ffn"] = layers.ffn_init(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return p


def _apply_ffn(bp, cfg: ModelConfig, kind: str, x):
    """Returns (y, aux)."""
    if kind == "moe":
        return moe_lib.moe_apply(bp["ffn"], cfg, x)
    if "ffn" in bp:
        return layers.ffn(bp["ffn"], x, cfg.activation), jnp.float32(0.0)
    return jnp.zeros_like(x), jnp.float32(0.0)


def _attn_window(cfg: ModelConfig) -> int:
    return cfg.window if cfg.attention == "swa" else 0


def block_apply_train(bp, cfg: ModelConfig, kind: str, x, positions, chunk: int):
    h = layers.rmsnorm(bp["norm1"], x, cfg.norm_eps)
    if kind in RECURRENT_KINDS:
        mix = recurrent.TRAIN[kind](bp["mix"], cfg, h)
    elif cfg.attention == "nsa":
        mix, _ = nsa_lib.attend_train_nsa(bp["mix"], cfg, h, positions, chunk=chunk)
    elif cfg.attention_impl == "flash":
        mix, _ = attention.attend_train_flash(bp["mix"], cfg, h, positions,
                                              window=_attn_window(cfg))
    elif cfg.attention_impl == "online":
        mix, _ = attention.attend_train_online(bp["mix"], cfg, h, positions,
                                               window=_attn_window(cfg))
    else:
        mix, _ = attention.attend_train(
            bp["mix"], cfg, h, positions, window=_attn_window(cfg), chunk=chunk,
            remat_chunks=(cfg.attention_impl == "chunked_remat"))
    x = x + mix
    h = layers.rmsnorm(bp["norm2"], x, cfg.norm_eps)
    y, aux = _apply_ffn(bp, cfg, kind, h)
    return x + y, aux


# ------------------------------------------------------------------ init
def init(key, cfg: ModelConfig):
    dtype = layers.dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {"embed": layers.embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.lm_head_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)
    params["final_norm"] = layers.rmsnorm_init(cfg.d_model, dtype)
    if cfg.modality != "text" and cfg.frontend_dim:
        params["frontend_proj"] = layers.linear_init(keys[2], cfg.frontend_dim, cfg.d_model, dtype)
    segs = []
    for si, (kinds, n) in enumerate(segments(cfg)):
        seg_key = jax.random.fold_in(keys[3], si)
        stacked = []
        for j, kind in enumerate(kinds):
            jkeys = jax.random.split(jax.random.fold_in(seg_key, j), n)
            stacked.append(jax.vmap(lambda k: block_init(k, cfg, kind, dtype))(jkeys))
        segs.append(tuple(stacked))
    params["segments"] = segs
    return params


# ------------------------------------------------------------------ embedding
def embed_inputs(params, cfg: ModelConfig, tokens, frontend=None):
    """Returns (x (B, S_total, d), positions (B, S_total), n_prefix)."""
    x = layers.embed(params["embed"], tokens)
    n_prefix = 0
    if frontend is not None and "frontend_proj" in params:
        fx = frontend.astype(x.dtype) @ params["frontend_proj"]["w"]
        x = jnp.concatenate([fx, x], axis=1)
        n_prefix = frontend.shape[1]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions, n_prefix


# ------------------------------------------------------------------ train fwd
def forward_train(params, cfg: ModelConfig, tokens, frontend=None, remat: bool = True,
                  attn_chunk: int = 512, constrain=None):
    """``constrain`` (optional) re-asserts the residual-stream sharding on the
    scan carry between layers — at scale this pins the stored (rematerialized)
    activations to a sequence-parallel layout (see launch/sharding.py)."""
    x, positions, n_prefix = embed_inputs(params, cfg, tokens, frontend)
    if constrain is not None:
        x = constrain(x)
    aux_total = jnp.float32(0.0)
    for (kinds, n), stacked in zip(segments(cfg), params["segments"]):
        def body(carry, gp, kinds=kinds):
            h, aux = carry
            for j, kind in enumerate(kinds):
                h, a = block_apply_train(gp[j], cfg, kind, h, positions, attn_chunk)
                aux = aux + a
            if constrain is not None:
                h = constrain(h)
            return (h, aux), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux_total, n_prefix


def logits_fn(params, cfg: ModelConfig, hidden):
    if cfg.tie_embeddings:
        return layers.unembed(params["embed"], hidden)
    return layers.lm_head(params["lm_head"], hidden)


def loss_fn(params, cfg: ModelConfig, tokens, frontend=None, remat: bool = True,
            loss_chunk: int = 512, aux_weight: float = 0.01, attn_chunk: int = 512,
            constrain=None):
    """Next-token cross-entropy, chunked over the sequence so the (chunk, V)
    logits working set stays bounded for 256K vocabularies."""
    hidden, aux, n_prefix = forward_train(params, cfg, tokens, frontend, remat,
                                          attn_chunk, constrain)
    B, S_tok = tokens.shape
    # predict tokens[t+1] from hidden at prefix+t
    h_pred = hidden[:, n_prefix : n_prefix + S_tok - 1]
    labels = tokens[:, 1:]
    S = h_pred.shape[1]
    chunk = min(loss_chunk, S)
    while S % chunk:
        chunk -= 1
    nchunk = S // chunk
    hc = h_pred.reshape(B, nchunk, chunk, cfg.d_model).swapaxes(0, 1)
    lc = labels.reshape(B, nchunk, chunk).swapaxes(0, 1)

    def body(tot, xs):
        h, l = xs
        logits = logits_fn(params, cfg, h).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, lc))
    loss = total / (B * S)
    return loss + aux_weight * aux


# ------------------------------------------------------------------ caches
def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype,
                     store: Optional[kvstore.KVStoreConfig] = None):
    if kind in RECURRENT_KINDS:
        return {"state": recurrent.STATE_INITS[kind](cfg, batch)}
    c = {"kv": kvstore.init_kv(cfg, batch, max_len, dtype,
                               store or kvstore.DENSE)}
    if cfg.attention == "nsa":
        c["cmp"] = nsa_lib.init_cmp_cache(cfg, batch, max_len, dtype, store)
    return c


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                store: Optional[kvstore.KVStoreConfig] = None):
    """Serving caches. Dense (default): raw-KV leaves (B, max_len, Hkv, Dh).
    Paged store: raw-KV leaves are the shared page pool (P, page_size, Hkv,
    Dh) — the engine owns the (B, max_pages) page table and threads it in as
    ``caches["pages"]``; cmp / recurrent leaves stay row-batched."""
    dtype = layers.dtype_of(cfg.dtype)
    caches = []
    for (kinds, n) in segments(cfg):
        stacked = []
        for kind in kinds:
            one = init_block_cache(cfg, kind, batch, max_len, dtype, store)
            stacked.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy() if n > 1 else a[None], one))
        caches.append(tuple(stacked))
    return {"segments": caches, "length": jnp.int32(0)}


# ------------------------------------------------------------------ prefill
def prefill(params, cfg: ModelConfig, tokens, max_len: int, frontend=None,
            attn_chunk: int = 512, constrain=None):
    """Run the full prompt, build caches. Returns (hidden (B,S,d), caches)."""
    dtype = layers.dtype_of(cfg.dtype)
    x, positions, n_prefix = embed_inputs(params, cfg, tokens, frontend)
    if constrain is not None:
        x = constrain(x)
    B, S, _ = x.shape
    assert S <= max_len
    seg_caches = []
    for (kinds, n), stacked in zip(segments(cfg), params["segments"]):
        def body(h, gp, kinds=kinds):
            caches_out = []
            for j, kind in enumerate(kinds):
                bp = gp[j]
                hn = layers.rmsnorm(bp["norm1"], h, cfg.norm_eps)
                if kind in RECURRENT_KINDS:
                    state0 = recurrent.STATE_INITS[kind](cfg, B)
                    if kind == "rglru":
                        mix, state = _rglru_prefill(bp["mix"], cfg, hn)
                    else:
                        mix, state = _xlstm_prefill(kind, bp["mix"], cfg, hn)
                    caches_out.append({"state": state})
                elif cfg.attention == "nsa":
                    mix, (k, v) = nsa_lib.attend_train_nsa(bp["mix"], cfg, hn, positions,
                                                           chunk=attn_chunk)
                    cache = attention.init_cache(cfg, B, max_len, dtype)
                    cache = attention.write_cache(cache, k, v, 0)
                    cmp = nsa_lib.init_cmp_cache(cfg, B, max_len, dtype)
                    k_cmp, v_cmp = nsa_lib.compress_kv(bp["mix"], k, v, cfg.nsa)
                    ncb = k_cmp.shape[1]
                    if ncb:
                        cmp = {"k_cmp": jax.lax.dynamic_update_slice_in_dim(
                                   cmp["k_cmp"], k_cmp.astype(dtype), 0, axis=1),
                               "v_cmp": jax.lax.dynamic_update_slice_in_dim(
                                   cmp["v_cmp"], v_cmp.astype(dtype), 0, axis=1)}
                    caches_out.append({"kv": cache, "cmp": cmp})
                elif cfg.attention_impl == "flash":
                    mix, (k, v) = attention.attend_train_flash(
                        bp["mix"], cfg, hn, positions, window=_attn_window(cfg))
                    cache = attention.init_cache(cfg, B, max_len, dtype)
                    cache = attention.write_cache(cache, k, v, 0)
                    caches_out.append({"kv": cache})
                else:
                    mix, (k, v) = attention.attend_train(bp["mix"], cfg, hn, positions,
                                                         window=_attn_window(cfg),
                                                         chunk=attn_chunk)
                    cache = attention.init_cache(cfg, B, max_len, dtype)
                    cache = attention.write_cache(cache, k, v, 0)
                    caches_out.append({"kv": cache})
                h = h + mix
                hn = layers.rmsnorm(bp["norm2"], h, cfg.norm_eps)
                y, _ = _apply_ffn(bp, cfg, kind, hn)
                h = h + y
            if constrain is not None:
                h = constrain(h)
            return h, tuple(caches_out)

        x, caches = jax.lax.scan(body, x, stacked)
        seg_caches.append(caches)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, {"segments": seg_caches, "length": jnp.int32(S)}


def _rglru_prefill(p, cfg, x):
    out = recurrent.rglru_apply_train(p, cfg, x)
    # recover final state: rerun coefficient path for last position via scan-free math
    u0 = x @ p["w_in"]
    u, _ = recurrent._causal_conv(p["conv"], u0)
    a, b = recurrent._rglru_coeffs(p, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    cw = p["conv"].shape[0]
    pad = jnp.concatenate([jnp.zeros((x.shape[0], cw - 1, u0.shape[-1]), u0.dtype), u0], axis=1)
    return out, {"h": hh[:, -1], "conv": pad[:, -(cw - 1):] if cw > 1 else pad[:, :0]}


def _xlstm_prefill(kind, p, cfg, x):
    B, S, d = x.shape
    state = recurrent.STATE_INITS[kind](cfg, B)
    step = recurrent.STEPS[kind]

    def body(st, t):
        xt = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=1)
        out, st2 = step(p, cfg, xt, st)
        return st2, out[:, 0]

    state, outs = jax.lax.scan(body, state, jnp.arange(S))
    return outs.swapaxes(0, 1), state


# ------------------------------------------------------------------ decode / verify
def _reuse_layer_flags(cfg: ModelConfig, ssv: Optional[SSVConfig]):
    """Per-layer bool: True if the layer REUSES inherited indices.
    Layer 0 is a mandatory refresh (paper §5.2)."""
    L = cfg.num_layers
    flags = np.zeros((L,), bool)
    if ssv is not None:
        for i in ssv.refresh_schedule:
            if 0 <= i < L:
                flags[i] = True
    flags[0] = False
    return flags


def _mix_verify(bp, cfg: ModelConfig, kind: str, h, cache, prefix_len, positions,
                tree_mask, parents, carry_idx, reuse_flag, ssv: Optional[SSVConfig],
                pages=None):
    """Sequence-mix a block in verify mode. Returns (mix_out, cache_updates,
    new_carry_idx). ``pages`` is the request-shared page table under the
    paged KV store (None = dense layout)."""
    B, T, _ = h.shape
    if kind in RECURRENT_KINDS:
        step = recurrent.STEPS[kind]
        outs, buf = recurrent.verify_states(step, bp["mix"], cfg, h, parents,
                                            cache["state"])
        return outs, {"state_buf": buf}, carry_idx
    kv = kvstore.as_view(cache["kv"], pages)
    if cfg.attention == "nsa":
        def fresh(_):
            q, _, _ = attention.qkv(bp["mix"], cfg, h, positions)
            _, p_slc = nsa_lib.routing(bp["mix"], cfg, q, cache["cmp"]["k_cmp"],
                                       cache["cmp"]["v_cmp"], positions,
                                       kv_len=kv.max_len,
                                       ncb_valid=nsa_lib.dyn_num_cmp_blocks(prefix_len, cfg.nsa))
            idx, val = nsa_lib.select_topn(p_slc, positions, prefix_len, cfg.nsa)
            if ssv is not None and ssv.group_mode == "approx" and ssv.group_size > 1:
                from repro.core.overlap import shared_index
                idx, val = shared_index(idx, val, positions, ssv.group_size)
            return idx, val

        def inherit(c):
            return c

        carry_idx = jax.lax.cond(reuse_flag, inherit, fresh, carry_idx)
        sel_idx, sel_valid = carry_idx
        out, (k_new, v_new), _ = nsa_lib.nsa_verify_ref(
            bp["mix"], cfg, h, kv, cache["cmp"], prefix_len, positions,
            tree_mask, sel_idx=sel_idx, sel_valid=sel_valid)
        return out, {"k_new": k_new, "v_new": v_new}, carry_idx
    out, (k_new, v_new) = attention.attend_verify(bp["mix"], cfg, h, kv,
                                                  prefix_len, positions, tree_mask,
                                                  window=_attn_window(cfg))
    return out, {"k_new": k_new, "v_new": v_new}, carry_idx


def verify_step(params, cfg: ModelConfig, caches, draft_tokens, positions, tree_mask,
                parents, ssv: Optional[SSVConfig] = None):
    """Verify gamma draft tokens against the committed caches.

    draft_tokens: (B, T); positions: (B, T) absolute; tree_mask (B, T, T);
    parents (T,) int32 (-1 = root attaches to committed prefix).

    Returns (logits (B, T, V), updates) where updates carries per-layer draft
    K/V (attention) or per-node state buffers (recurrent) for committing.
    """
    prefix_len = caches["length"]
    x = layers.embed(params["embed"], draft_tokens)
    B, T, _ = x.shape
    # carry for refresh/reuse index inheritance
    if cfg.attention == "nsa":
        nsb_max = nsa_lib.num_sel_blocks(_max_len_of(caches), cfg.nsa)
        n_idx = min(cfg.nsa.n_selected, max(nsb_max, 1))
        carry_idx = (jnp.zeros((B, T, cfg.num_kv_heads, n_idx), jnp.int32),
                     jnp.zeros((B, T, cfg.num_kv_heads, n_idx), bool))
    else:
        carry_idx = (jnp.zeros((B, T, 1, 1), jnp.int32), jnp.zeros((B, T, 1, 1), bool))

    flags = _reuse_layer_flags(cfg, ssv)
    li = 0
    seg_updates = []
    for (kinds, ngroups), stacked, seg_caches in zip(segments(cfg), params["segments"],
                                                     caches["segments"]):
        m = len(kinds)
        seg_flags = flags[li : li + ngroups * m].reshape(ngroups, m)
        li += ngroups * m

        def body(carry, xs, kinds=kinds):
            h, cidx = carry
            gp, gcache, gflags = xs
            ups = []
            for j, kind in enumerate(kinds):
                hn = layers.rmsnorm(gp[j]["norm1"], h, cfg.norm_eps)
                mix, up, cidx = _mix_verify(gp[j], cfg, kind, hn, gcache[j], prefix_len,
                                            positions, tree_mask, parents, cidx,
                                            gflags[j], ssv, pages=caches.get("pages"))
                h = h + mix
                hn = layers.rmsnorm(gp[j]["norm2"], h, cfg.norm_eps)
                y, _ = _apply_ffn(gp[j], cfg, kind, hn)
                h = h + y
                ups.append(up)
            return (h, cidx), tuple(ups)

        (x, carry_idx), updates = jax.lax.scan(
            body, (x, carry_idx), (stacked, seg_caches, jnp.asarray(seg_flags)))
        seg_updates.append(updates)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_fn(params, cfg, x)
    return logits, seg_updates


def _max_len_of(caches):
    pages = caches.get("pages")
    for seg in caches["segments"]:
        for c in seg:
            if "kv" in c:
                if pages is not None:
                    # stacked pool: (n, P, page_size, Hkv, Dh); logical
                    # capacity = pages per row x page size
                    return pages.shape[1] * c["kv"]["k"].shape[2]
                return c["kv"]["k"].shape[2]  # stacked: (n, B, S, Hkv, Dh)
    return 0


def decode_step(params, cfg: ModelConfig, caches, tokens, ssv: Optional[SSVConfig] = None):
    """One autoregressive step: tokens (B, 1). Returns (logits, new caches)."""
    B = tokens.shape[0]
    T = 1
    positions = jnp.broadcast_to(caches["length"][None, None], (B, 1)).astype(jnp.int32)
    tree_mask = jnp.ones((B, 1, 1), bool)
    parents = jnp.full((1,), -1, jnp.int32)
    logits, seg_updates = verify_step(params, cfg, caches, tokens, positions,
                                      tree_mask, parents, ssv)
    new_caches = commit(params, cfg, caches, seg_updates,
                        accepted=jnp.zeros((B, 1), jnp.int32),
                        n_accepted=jnp.ones((B,), jnp.int32))
    return logits, new_caches


def commit(params, cfg: ModelConfig, caches, seg_updates, accepted, n_accepted):
    """Commit accepted draft tokens into the caches.

    accepted: (B, T_acc) node indices into the draft batch (a root-to-leaf
    path, padded with the last valid entry); n_accepted: (B,) how many are
    real. Appends accepted K/V (or selects the accepted recurrent state) and
    advances length. All shapes static; garbage beyond n_accepted is masked
    by `length` downstream. A row with n_accepted == 0 is a no-op commit
    (length frozen, recurrent state preserved) — batched serving uses this to
    freeze finished requests while the rest of the batch keeps stepping.

    Paged caches (``"pages"`` present) route through the prepare/apply pair
    below: accepted K/V scatter into the shared page pool through the page
    table instead of a dense slice write.
    """
    if "pages" in caches:
        prep, new_len = commit_paged_prepare(params, cfg, caches, seg_updates,
                                             accepted, n_accepted)
        segs = commit_apply_paged(caches["segments"], prep, caches["pages"],
                                  caches["length"], n_accepted)
        return {"segments": segs, "length": new_len, "pages": caches["pages"]}
    old_len = caches["length"]
    B, T_acc = accepted.shape
    # NOTE: batched serving commits per-row lengths; the engine uses B==1 per
    # sequence group, so a scalar length is sound here.
    new_len = old_len + n_accepted[0]
    max_new_cmp = (T_acc // cfg.nsa.cmp_stride) + 2
    new_segs = []
    for (kinds, ngroups), stacked, seg_caches, updates in zip(
            segments(cfg), params["segments"], caches["segments"], seg_updates):
        new_stack = []
        for j, kind in enumerate(kinds):
            cache_j = seg_caches[j]
            up_j = updates[j]
            if kind in RECURRENT_KINDS:
                new_stack.append({"state": _pick_recurrent(cache_j, up_j,
                                                           accepted, n_accepted)})
                continue
            # attention: gather accepted K/V along the draft axis and append
            k_acc, v_acc = _gather_accepted(up_j, accepted)
            kv = cache_j["kv"]
            k_cache = jax.vmap(lambda c, kn: jax.lax.dynamic_update_slice_in_dim(
                c, kn.astype(c.dtype), old_len, axis=1))(kv["k"], k_acc)
            v_cache = jax.vmap(lambda c, vn: jax.lax.dynamic_update_slice_in_dim(
                c, vn.astype(c.dtype), old_len, axis=1))(kv["v"], v_acc)
            new_c = {"kv": {"k": k_cache, "v": v_cache}}
            if "cmp" in cache_j:
                new_c["cmp"] = jax.vmap(
                    lambda p, kvc, cmpc: nsa_lib.update_cmp_cache_dyn(
                        p, kvc, cmpc, old_len, new_len, max_new_cmp, cfg.nsa),
                    in_axes=(0, 0, 0))(stacked[j]["mix"], new_c["kv"], cache_j["cmp"])
            new_stack.append(new_c)
        new_segs.append(tuple(new_stack))
    return {"segments": new_segs, "length": new_len}


def _gather_accepted(up_j, accepted):
    """Pick the accepted root-to-leaf path's K/V out of a layer's draft
    updates: (n, B, T, Hkv, Dh) -> (n, B, T_acc, Hkv, Dh)."""
    B, T_acc = accepted.shape
    k_new, v_new = up_j["k_new"], up_j["v_new"]
    gi = accepted[None, :, :, None, None]
    k_acc = jnp.take_along_axis(k_new, jnp.broadcast_to(
        gi, (k_new.shape[0], B, T_acc) + k_new.shape[3:]), axis=2)
    v_acc = jnp.take_along_axis(v_new, jnp.broadcast_to(
        gi, (v_new.shape[0], B, T_acc) + v_new.shape[3:]), axis=2)
    return k_acc, v_acc


def _pick_recurrent(cache_j, up_j, accepted, n_accepted):
    """Accepted-state selection for a recurrent layer (shared by the dense
    and paged commits): take the state after the deepest accepted node, keep
    the old state for rows with nothing accepted."""
    B = accepted.shape[0]
    buf = up_j["state_buf"]          # leaves: (n, T+1, B, ...)
    last = accepted[:, -1]           # (B,)

    def pick(b):
        idx = jnp.clip(last + 1, 0, b.shape[1] - 1)
        idxe = idx.reshape((1, 1, B) + (1,) * (b.ndim - 3))
        g = jnp.take_along_axis(
            b, jnp.broadcast_to(idxe, (b.shape[0], 1, B) + b.shape[3:]), axis=1)
        return g[:, 0]

    new_state = jax.tree.map(pick, buf)
    live = n_accepted > 0

    def keep(ns, o):
        m = live.reshape((1, B) + (1,) * (ns.ndim - 2))
        return jnp.where(m, ns.astype(o.dtype), o)

    return jax.tree.map(keep, new_state, cache_j["state"])


def commit_paged_prepare(params, cfg: ModelConfig, caches, seg_updates,
                         accepted, n_accepted):
    """Everything in a paged commit EXCEPT the page-pool writes.

    Per attention layer: the accepted K/V path (``{"acc": {"k", "v"}}``,
    (n, B, T_acc, Hkv, Dh)) plus the updated compression cache — computed
    against the *pre-write* pool with the accepted tokens overlaid, so it
    never depends on write ordering. Per recurrent layer: the selected
    state. Splitting prepare from apply lets the batched step run prepare
    inside its per-row vmap (pools are read-only there) and issue the shared
    -pool scatters once, at batch level, where rows cannot alias.
    Returns (prep segments, new_len)."""
    old_len = caches["length"]
    B, T_acc = accepted.shape
    new_len = old_len + n_accepted[0]
    max_new_cmp = (T_acc // cfg.nsa.cmp_stride) + 2
    pages = caches["pages"]
    prep = []
    for (kinds, ngroups), stacked, seg_caches, updates in zip(
            segments(cfg), params["segments"], caches["segments"], seg_updates):
        group = []
        for j, kind in enumerate(kinds):
            cache_j = seg_caches[j]
            up_j = updates[j]
            if kind in RECURRENT_KINDS:
                group.append({"state": _pick_recurrent(cache_j, up_j,
                                                       accepted, n_accepted)})
                continue
            k_acc, v_acc = _gather_accepted(up_j, accepted)
            entry = {"acc": {"k": k_acc, "v": v_acc}}
            if "cmp" in cache_j:
                def upd(p, pk, pv, cmpc, ka, va):
                    view = kvstore.KVView(pk, pv, pages)
                    return nsa_lib.update_cmp_cache_dyn(
                        p, view, cmpc, old_len, new_len, max_new_cmp, cfg.nsa,
                        overlay=(ka, va))
                entry["cmp"] = jax.vmap(upd)(
                    stacked[j]["mix"], cache_j["kv"]["k"], cache_j["kv"]["v"],
                    cache_j["cmp"], k_acc, v_acc)
            group.append(entry)
        prep.append(tuple(group))
    return prep, new_len


def commit_apply_paged(segs, prep, pages, old_len, n_accepted):
    """Apply a prepared paged commit to the cache segments: scatter each
    layer's accepted K/V into the shared page pool through the page table
    (rows with ``n_accepted == 0`` — finished slots whose pages may already
    belong to a new request — are dropped, not clamped) and swap in the
    prepared cmp / recurrent leaves.

    Works for the single-request caches (prep leaves (n, B, T_acc, ...),
    ``old_len`` scalar) and for the batched engine (prep leaves stacked to
    (n, R, T_acc, ...), ``old_len``/``n_accepted`` shaped (R,))."""
    mask = n_accepted > 0
    new_segs = []
    for seg_prep, seg_caches in zip(prep, segs):
        group = []
        for cp, cc in zip(seg_prep, seg_caches):
            if "state" in cp:
                group.append({"state": cp["state"]})
                continue
            kv = cc["kv"]

            def write_one(pk, pv, ka, va):
                view = kvstore.KVView(pk, pv, pages)
                return view.write(ka, va, old_len, row_mask=mask)

            k_pool, v_pool = jax.vmap(write_one)(kv["k"], kv["v"],
                                                 cp["acc"]["k"], cp["acc"]["v"])
            new_c = {"kv": {"k": k_pool, "v": v_pool}}
            if "cmp" in cp:
                new_c["cmp"] = cp["cmp"]
            group.append(new_c)
        new_segs.append(tuple(group))
    return new_segs
