"""Sequence-sharded NSA decode via shard_map — the §Perf optimization for
batch-1 long-context serving (long_500k cells).

Problem (measured in the baseline dry-run): with the KV cache sharded along
the sequence axis, XLA's SPMD partitioner cannot execute the selection-branch
gather or the sliding-window dynamic-slice locally — it falls back to
"involuntary full rematerialization" (replicating multi-GB cache slices), so
a single decoded token is COLLECTIVE-bound (≈1.5 s roofline for qwen3-8b at
524K context on the single-pod mesh).

Fix — the flash-decoding/split-KV pattern adapted to NSA's three branches.
Each shard owns a contiguous slice of the raw and compressed caches and
computes only over local data:

  1. local routing: q·K_cmp over local compressed blocks -> the cmp branch's
     local online-softmax state AND local partial selection-block scores;
  2. one psum of the (B, Hkv, NSB) partial score vector -> every shard
     derives the IDENTICAL exact global Top-n (mandatory blocks included);
  3. local gathers: the tokens of each selected block that live on this
     shard (token-granular ownership, so blocks may straddle shard
     boundaries), the local window segment, and (on shard 0 only) the new
     token itself -> local slc/win branch states;
  4. per-branch log-sum-exp merge across shards (psum of O(Hq·Dh) floats)
     and gated aggregation.

Wire bytes per layer-step: one (B,Hkv,NSB) psum + three O(B·Hq·Dh) merges —
microscopic next to the baseline's replicated cache slices. Cache commits
(raw K/V + freshly completed compressed blocks) happen shard-locally inside
the same shard_map. Exact semantics vs nsa.nsa_verify_ref (T=1) up to
reduction order — tests/test_distributed_nsa.py.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, NSAConfig
from repro.models import layers
from repro.models.attention import NEG_INF, qkv
from repro.models.nsa import dyn_num_cmp_blocks, gates, select_topn


def _state(logits, mask, v):
    """Branch state: logits (B,Hkv,Gq,K), mask (B,1|Hkv,1,K)-broadcastable,
    v (B,K,Hkv,Dh) -> m,l (B,Hkv,Gq), acc (B,Hkv,Gq,Dh)."""
    lm = jnp.where(mask, logits, NEG_INF)
    m = lm.max(-1)
    p = jnp.exp(lm - m[..., None]) * mask
    l = p.sum(-1)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return m, l, acc


def _merge(states, axis):
    """LSE-merge branch states across shards. states = (m, l, acc)."""
    m, l, acc = states
    m_max = jax.lax.pmax(m, axis)
    s = jnp.exp(m - m_max)
    l_g = jax.lax.psum(l * s, axis)
    acc_g = jax.lax.psum(acc * s[..., None], axis)
    return jnp.where(l_g[..., None] > 0,
                     acc_g / jnp.maximum(l_g, 1e-30)[..., None], 0.0)


def nsa_attend_decode_sharded(params, cfg: ModelConfig, mesh, x, cache,
                              cmp_cache, prefix_len, seq_axes: Tuple[str, ...]):
    """One-token NSA attention + cache commit over a sequence-sharded cache.

    x: (B, 1, D). cache k/v: (B, S, Hkv, Dh) sharded on dim 1 over seq_axes;
    cmp_cache likewise. Returns (out (B,1,D), new cache, new cmp_cache).
    """
    nsa = cfg.nsa
    B = x.shape[0]
    Hq, Hkv, Gq, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    n = nsa.n_selected
    S = cache["k"].shape[1]
    NCB = cmp_cache["k_cmp"].shape[1]
    NSB = -(-S // nsa.sel_block)
    nshards = int(np.prod([mesh.shape[a] for a in seq_axes]))
    S_loc, NCB_loc = S // nshards, NCB // nshards
    axis = seq_axes if len(seq_axes) > 1 else seq_axes[0]

    positions = jnp.broadcast_to(jnp.asarray(prefix_len)[None, None], (B, 1))
    q, k_new, v_new = qkv(params, cfg, x, positions.astype(jnp.int32))
    g_all = gates(params, x, Hq)                                   # (B,1,3,Hq)
    scale = 1.0 / np.sqrt(Dh)
    ncb_valid = dyn_num_cmp_blocks(prefix_len, nsa)

    # static overlap geometry: cmp block i -> fractional weight onto sel blocks
    from repro.models.nsa import overlap_matrix
    M_full = jnp.asarray(overlap_matrix(NCB, NSB, nsa.cmp_block, nsa.cmp_stride,
                                        nsa.sel_block))            # (NCB, NSB)

    def body(q, k_new, v_new, g_all, k_c, v_c, k_cm, v_cm, prefix_len, ncb_valid):
        # shard-local slices: k_c (B, S_loc, Hkv, Dh), k_cm (B, NCB_loc, Hkv, Dh)
        if isinstance(axis, tuple):
            idx = sum(jax.lax.axis_index(a) *
                      int(np.prod([mesh.shape[b] for b in axis[i + 1:]]))
                      for i, a in enumerate(axis))
        else:
            idx = jax.lax.axis_index(axis)
        off = idx * S_loc
        cmp_off = idx * NCB_loc
        pos = jnp.asarray(prefix_len)                              # scalar
        qg = (q.reshape(B, 1, Hkv, Gq, Dh)[:, 0] * scale).astype(jnp.float32)

        # ---- 1+2. local routing + cmp branch state
        cmp_ids = cmp_off + jnp.arange(NCB_loc)
        ends = cmp_ids * nsa.cmp_stride + nsa.cmp_block - 1
        cvis = (ends <= pos) & (cmp_ids < ncb_valid)               # (NCB_loc,)
        lc = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cm.astype(jnp.float32))
        cmask = jnp.broadcast_to(cvis[None, None, None], lc.shape)
        st_cmp = _state(lc, cmask, v_cm)

        # partial selection scores: exp(l - m_glob) mass mapped onto blocks
        m_glob = jax.lax.pmax(jnp.where(cmask, lc, NEG_INF).max((-1)), axis)
        pmass = jnp.exp(jnp.where(cmask, lc, NEG_INF) - m_glob[..., None]) * cmask
        # GQA-share: sum over the Gq query heads of each kv group
        pm = pmass.sum(2)                                          # (B,Hkv,NCB_loc)
        M_loc = jax.lax.dynamic_slice_in_dim(M_full, cmp_off, NCB_loc, axis=0)
        p_slc = jax.lax.psum(jnp.einsum("bhk,ks->bhs", pm, M_loc), axis)

        # ---- exact global Top-n (identical on every shard)
        sel_idx, sel_valid = select_topn(p_slc[:, None], positions, pos, nsa)
        sel_idx, sel_valid = sel_idx[:, 0], sel_valid[:, 0]        # (B,Hkv,n)

        # ---- 3a. slc branch: token-granular local ownership
        tok = sel_idx[..., None] * nsa.sel_block + jnp.arange(nsa.sel_block)
        tok = tok.reshape(B, Hkv, n * nsa.sel_block)               # (B,Hkv,K)
        ownm = (tok >= off) & (tok < off + S_loc) & (tok < pos) & \
            jnp.repeat(sel_valid, nsa.sel_block, axis=-1)
        loc = jnp.clip(tok - off, 0, S_loc - 1)
        bidx = jnp.arange(B)[:, None, None]
        hidx = jnp.arange(Hkv)[None, :, None]
        k_sel = k_c[bidx, loc, hidx]                               # (B,Hkv,K,Dh)
        v_sel = v_c[bidx, loc, hidx]
        ls = jnp.einsum("bhgd,bhkd->bhgk", qg, k_sel.astype(jnp.float32))
        m_s = ownm[:, :, None]                                      # (B,Hkv,1,K)
        lm = jnp.where(m_s, ls, NEG_INF)
        m1 = lm.max(-1)
        p1 = jnp.exp(lm - m1[..., None]) * m_s
        l1 = p1.sum(-1)
        a1 = jnp.einsum("bhgk,bhkd->bhgd", p1, v_sel.astype(jnp.float32))
        st_slc = (m1, l1, a1)

        # ---- 3b. win branch: local window segment (+ the new token, shard 0)
        W = min(nsa.window, S_loc)
        wstart_g = jnp.clip(pos - nsa.window + 1, 0, S - 1)  # (pos-w, pos) open
        lstart = jnp.clip(wstart_g - off, 0, max(S_loc - W, 0))
        k_w = jax.lax.dynamic_slice_in_dim(k_c, lstart, W, axis=1)
        v_w = jax.lax.dynamic_slice_in_dim(v_c, lstart, W, axis=1)
        wpos = off + lstart + jnp.arange(W)
        wmask = (wpos < pos) & (wpos >= wstart_g) & (wpos < off + S_loc)
        lw = jnp.einsum("bhgd,bkhd->bhgk", qg, k_w.astype(jnp.float32))
        st_win = _state(lw, jnp.broadcast_to(wmask[None, None, None], lw.shape), v_w)
        # new token: contributes once (shard 0)
        lnew = jnp.einsum("bhgd,bkhd->bhgk", qg, k_new.astype(jnp.float32))
        nmask = jnp.broadcast_to(jnp.reshape(idx == 0, (1, 1, 1, 1)), lnew.shape)
        mw, lw_, aw = st_win
        mn, ln_, an = _state(lnew, nmask, v_new)
        m2 = jnp.maximum(mw, mn)
        s_w, s_n = jnp.exp(mw - m2), jnp.exp(mn - m2)
        st_win = (m2, lw_ * s_w + ln_ * s_n,
                  aw * s_w[..., None] + an * s_n[..., None])

        # ---- 4. merge + gates
        o_cmp = _merge(st_cmp, axis)
        o_slc = _merge(st_slc, axis)
        o_win = _merge(st_win, axis)
        g = g_all[:, 0].reshape(B, 3, Hkv, Gq)
        o = (g[:, 0, :, :, None] * o_cmp + g[:, 1, :, :, None] * o_slc +
             g[:, 2, :, :, None] * o_win)
        o = o.reshape(B, 1, Hq * Dh)

        # ---- shard-local cache commit (raw KV at position `pos`)
        in_range = (pos >= off) & (pos < off + S_loc)
        wr = jnp.clip(pos - off, 0, S_loc - 1)
        k_upd = jax.lax.dynamic_update_slice_in_dim(
            k_c, jnp.where(in_range, k_new, jax.lax.dynamic_slice_in_dim(
                k_c, wr, 1, axis=1)).astype(k_c.dtype), wr, axis=1)
        v_upd = jax.lax.dynamic_update_slice_in_dim(
            v_c, jnp.where(in_range, v_new, jax.lax.dynamic_slice_in_dim(
                v_c, wr, 1, axis=1)).astype(v_c.dtype), wr, axis=1)
        return o, k_upd, v_upd

    specs_seq = P(None, seq_axes, None, None)
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), P(), P(), specs_seq, specs_seq, specs_seq,
                  specs_seq, P(), P()),
        out_specs=(P(), specs_seq, specs_seq),
        check_vma=False)
    o, k_upd, v_upd = fn(q, k_new, v_new, g_all, cache["k"], cache["v"],
                         cmp_cache["k_cmp"], cmp_cache["v_cmp"],
                         jnp.asarray(prefix_len), ncb_valid)
    out = o.astype(x.dtype) @ params["wo"]
    return out, {"k": k_upd, "v": v_upd}, cmp_cache


def decode_step_sharded(params, cfg: ModelConfig, mesh, caches, tokens,
                        seq_axes: Tuple[str, ...]):
    """Full-model one-token decode with sequence-sharded NSA attention.
    Matches model.decode_step semantics for homogeneous attn/moe stacks with
    cfg.attention == 'nsa' (the long_500k serving configuration).

    NOTE: compressed-cache incremental updates append at block granularity
    (a new block completes every cmp_stride tokens); the update is shard-local
    by construction and folded into the serving engine's commit cadence —
    for the single-token dry-run step the cmp cache is read-only.
    """
    from repro.models import model as model_lib

    prefix_len = caches["length"]
    x = layers.embed(params["embed"], tokens)
    new_segs = []
    for (kinds, ngroups), stacked, seg_caches in zip(
            model_lib.segments(cfg), params["segments"], caches["segments"]):
        def body(h, xs, kinds=kinds):
            gp, gcache = xs
            new_cache = []
            for j, kind in enumerate(kinds):
                bp = gp[j]
                hn = layers.rmsnorm(bp["norm1"], h, cfg.norm_eps)
                mix, kv, cmp = nsa_attend_decode_sharded(
                    bp["mix"], cfg, mesh, hn, gcache[j]["kv"], gcache[j]["cmp"],
                    prefix_len, seq_axes)
                h = h + mix
                hn = layers.rmsnorm(bp["norm2"], h, cfg.norm_eps)
                y, _ = model_lib._apply_ffn(bp, cfg, kind, hn)
                h = h + y
                new_cache.append({"kv": kv, "cmp": cmp})
            return h, tuple(new_cache)

        x, seg_new = jax.lax.scan(body, x, (stacked, seg_caches))
        new_segs.append(seg_new)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = model_lib.logits_fn(params, cfg, x)
    return logits, {"segments": new_segs, "length": prefix_len + 1}
