"""Native Sparse Attention (NSA) in pure JAX — the target-model attention
backend that SSV verifies against.

NSA (Yuan et al., ACL 2025) fuses three branches with learned per-head gates:
  cmp — attention over compressed KV blocks (length l, stride d)
  slc — attention over Top-n *selected* raw KV blocks (size l'), routed by
        compressed-attention scores (GQA-group shared)
  win — dense sliding window over the last w tokens

This module provides:
  * parameter init (projections + compression pooling + gates)
  * compression-cache construction / incremental update
  * routing: cmp scores -> selection-block scores -> Top-n indices
  * three execution modes:
      - train/prefill: mask-based (exact semantics, chunked, O(S·S) compute
        upper bound but no gather blow-up; what the dry-run lowers)
      - decode: true sparse gather for a single query
      - verify: gamma tree-masked draft queries with *external* per-query
        selected indices (supplied by core/verify.py, which implements the
        paper's refresh/reuse + exact/approx grouping policies)

Compression uses learned softmax position-pooling plus a per-head linear
projection — a TPU-friendly stand-in for NSA's block MLP (same information
flow: intra-block position-aware learned aggregation). Noted in DESIGN.md.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, NSAConfig
from repro.core import kvstore
from repro.models import layers
from repro.models.attention import NEG_INF, attn_init, qkv


# ---------------------------------------------------------------- init
def nsa_init(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = attn_init(ks[0], cfg, dtype)
    nsa = cfg.nsa
    p["phi_k"] = jnp.zeros((nsa.cmp_block,), jnp.float32)     # softmax pooling logits
    p["phi_v"] = jnp.zeros((nsa.cmp_block,), jnp.float32)
    p["w_cmp_k"] = (jnp.eye(cfg.head_dim) +
                    0.02 * jax.random.normal(ks[1], (cfg.head_dim, cfg.head_dim))).astype(dtype)
    p["w_cmp_v"] = (jnp.eye(cfg.head_dim) +
                    0.02 * jax.random.normal(ks[2], (cfg.head_dim, cfg.head_dim))).astype(dtype)
    # per-head gates for (cmp, slc, win); bias init so win starts dominant
    p["w_gate"] = (jax.random.normal(ks[3], (cfg.d_model, 3 * cfg.num_heads)) * 0.01).astype(dtype)
    p["b_gate"] = jnp.zeros((3 * cfg.num_heads,), jnp.float32)
    return p


# ---------------------------------------------------------------- geometry
def num_cmp_blocks(P: int, nsa: NSAConfig) -> int:
    return 0 if P < nsa.cmp_block else (P - nsa.cmp_block) // nsa.cmp_stride + 1


def num_sel_blocks(P: int, nsa: NSAConfig) -> int:
    return max(0, -(-P // nsa.sel_block))


@functools.lru_cache(maxsize=64)
def overlap_matrix(ncb: int, nsb: int, l: int, d: int, lp: int) -> np.ndarray:
    """Fractional overlap M[i, j] of cmp block i (start i*d, len l) with sel
    block j (start j*lp, len lp): used to map cmp-attention probability mass
    onto selection blocks (NSA eq. 9 generalized to l' != d)."""
    i = np.arange(ncb)[:, None]
    j = np.arange(nsb)[None, :]
    lo = np.maximum(i * d, j * lp)
    hi = np.minimum(i * d + l, (j + 1) * lp)
    return (np.maximum(0, hi - lo) / float(l)).astype(np.float32)


def cmp_visible_mask(positions, ncb: int, nsa: NSAConfig):
    """cmp block i fully precedes query at pos p iff i*d + l - 1 <= p.
    positions: (..., T) -> mask (..., T, ncb)."""
    ends = jnp.arange(ncb) * nsa.cmp_stride + nsa.cmp_block - 1
    return ends[None, :] <= positions[..., None]


# ---------------------------------------------------------------- compression
def compress_kv(params, k, v, nsa: NSAConfig):
    """k, v: (B, S, Hkv, Dh) -> (B, NCB, Hkv, Dh) with NCB = num_cmp_blocks(S).

    Strided blocks are materialized as a gather of shape (NCB, l); softmax
    position pooling then projects each block to one compressed KV pair.
    """
    B, S, H, Dh = k.shape
    ncb = num_cmp_blocks(S, nsa)
    if ncb == 0:
        z = jnp.zeros((B, 0, H, Dh), k.dtype)
        return z, z
    starts = np.arange(ncb) * nsa.cmp_stride
    idx = starts[:, None] + np.arange(nsa.cmp_block)[None, :]        # (NCB, l)
    kb = jnp.take(k, jnp.asarray(idx), axis=1)                        # (B, NCB, l, H, Dh)
    vb = jnp.take(v, jnp.asarray(idx), axis=1)
    wk = jax.nn.softmax(params["phi_k"]).astype(jnp.float32)
    wv = jax.nn.softmax(params["phi_v"]).astype(jnp.float32)
    k_cmp = jnp.einsum("bnlhd,l->bnhd", kb.astype(jnp.float32), wk)
    v_cmp = jnp.einsum("bnlhd,l->bnhd", vb.astype(jnp.float32), wv)
    k_cmp = (k_cmp @ params["w_cmp_k"].astype(jnp.float32)).astype(k.dtype)
    v_cmp = (v_cmp @ params["w_cmp_v"].astype(jnp.float32)).astype(v.dtype)
    return k_cmp, v_cmp


def update_cmp_cache(params, cache, cmp_cache, old_len, new_len, nsa: NSAConfig):
    """Incrementally append compressed blocks that became complete when the
    committed prefix grew old_len -> new_len (static ints for the ref path)."""
    ncb_old, ncb_new = num_cmp_blocks(old_len, nsa), num_cmp_blocks(new_len, nsa)
    if ncb_new == ncb_old:
        return cmp_cache
    starts = np.arange(ncb_old, ncb_new) * nsa.cmp_stride
    idx = starts[:, None] + np.arange(nsa.cmp_block)[None, :]
    kb = jnp.take(cache["k"], jnp.asarray(idx), axis=1)
    vb = jnp.take(cache["v"], jnp.asarray(idx), axis=1)
    wk = jax.nn.softmax(params["phi_k"]).astype(jnp.float32)
    wv = jax.nn.softmax(params["phi_v"]).astype(jnp.float32)
    k_new = (jnp.einsum("bnlhd,l->bnhd", kb.astype(jnp.float32), wk)
             @ params["w_cmp_k"].astype(jnp.float32)).astype(cmp_cache["k_cmp"].dtype)
    v_new = (jnp.einsum("bnlhd,l->bnhd", vb.astype(jnp.float32), wv)
             @ params["w_cmp_v"].astype(jnp.float32)).astype(cmp_cache["v_cmp"].dtype)
    k_cmp = jax.lax.dynamic_update_slice_in_dim(cmp_cache["k_cmp"], k_new, ncb_old, axis=1)
    v_cmp = jax.lax.dynamic_update_slice_in_dim(cmp_cache["v_cmp"], v_new, ncb_old, axis=1)
    return {"k_cmp": k_cmp, "v_cmp": v_cmp}


def update_cmp_cache_dyn(params, cache, cmp_cache, old_len, new_len, max_new: int,
                         nsa: NSAConfig, overlay=None):
    """Traced-length incremental compression update for the jitted engine.

    old_len/new_len are traced int32; at most ``max_new`` blocks can complete
    per commit (static bound: ceil((gamma+1)/stride)+1). Candidate blocks are
    computed unconditionally and masked into the cache.

    ``cache`` is a raw ``{"k", "v"}`` dict (dense) or a ``kvstore.KVView``
    over either backend. ``overlay`` = (k_acc, v_acc) of shape
    (B, T_acc, Hkv, Dh) supplies the tokens committed at ``old_len`` this
    step *before* they land in the store — the paged batched commit reads
    the fresh region from the accept buffer instead of ordering a pool
    write ahead of the compression update.
    """
    kv = kvstore.as_view(cache)
    ncb_old = dyn_num_cmp_blocks(old_len, nsa)
    ncb_new = dyn_num_cmp_blocks(new_len, nsa)
    B = kv.batch
    S = kv.max_len
    starts = (ncb_old + jnp.arange(max_new)) * nsa.cmp_stride          # (max_new,)
    idx = jnp.clip(starts[:, None] + jnp.arange(nsa.cmp_block)[None, :], 0, S - 1)
    kb, vb = kv.gather_tokens(jnp.broadcast_to(idx[None], (B,) + idx.shape))
    if overlay is not None:
        k_acc, v_acc = overlay                                         # (B,T_acc,H,Dh)
        T_acc = k_acc.shape[1]
        rel = jnp.clip(idx[None] - old_len, 0, T_acc - 1)              # (B?,max_new,l)
        rel = jnp.broadcast_to(rel, (B,) + idx.shape).reshape(B, -1)
        fresh = (idx[None] >= old_len) & (idx[None] < old_len + T_acc)
        fresh = jnp.broadcast_to(fresh, (B,) + idx.shape)[..., None, None]
        ko = jnp.take_along_axis(k_acc, rel[..., None, None], axis=1
                                 ).reshape(kb.shape)
        vo = jnp.take_along_axis(v_acc, rel[..., None, None], axis=1
                                 ).reshape(vb.shape)
        # cast to the store dtype first: the dense path reads these tokens
        # back from the cache after the write (post-rounding), and backend
        # token-equality requires bit-matching compression inputs
        kb = jnp.where(fresh, ko.astype(kb.dtype), kb)
        vb = jnp.where(fresh, vo.astype(vb.dtype), vb)
    wk = jax.nn.softmax(params["phi_k"]).astype(jnp.float32)
    wv = jax.nn.softmax(params["phi_v"]).astype(jnp.float32)
    k_new = (jnp.einsum("bnlhd,l->bnhd", kb.astype(jnp.float32), wk)
             @ params["w_cmp_k"].astype(jnp.float32))
    v_new = (jnp.einsum("bnlhd,l->bnhd", vb.astype(jnp.float32), wv)
             @ params["w_cmp_v"].astype(jnp.float32))
    valid = (ncb_old + jnp.arange(max_new)) < ncb_new                  # (max_new,)
    NCB = cmp_cache["k_cmp"].shape[1]
    slot = jnp.clip(ncb_old + jnp.arange(max_new), 0, NCB - 1)
    oh = (jax.nn.one_hot(slot, NCB, dtype=jnp.float32) * valid[:, None])  # (max_new,NCB)
    k_cmp = cmp_cache["k_cmp"].astype(jnp.float32) * (1 - oh.sum(0))[None, :, None, None] \
        + jnp.einsum("bnhd,nc->bchd", k_new, oh)
    v_cmp = cmp_cache["v_cmp"].astype(jnp.float32) * (1 - oh.sum(0))[None, :, None, None] \
        + jnp.einsum("bnhd,nc->bchd", v_new, oh)
    return {"k_cmp": k_cmp.astype(cmp_cache["k_cmp"].dtype),
            "v_cmp": v_cmp.astype(cmp_cache["v_cmp"].dtype)}


def init_cmp_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32,
                   store=None):
    """Compressed-KV cache. Under the paged store the compressed blocks stay
    row-dense on purpose: they are ``cmp_stride``x smaller than raw KV (the
    dominant term paging targets) and the routing launch reads them densely
    every step — paging them would turn one contiguous read into a gather
    for <7% of the KV footprint. ``store`` is accepted so call sites thread
    one handle; only the raw-KV layout changes with the backend."""
    del store
    ncb = num_cmp_blocks(max_len, cfg.nsa)
    # pad the block axis to a shardable multiple (512 covers the multi-pod
    # sequence-sharded layout); padded blocks are invisible to every query
    # (cmp_visible_mask + ncb_valid) so the values never matter
    pad_to = 512 if max_len >= 8192 else 8
    ncb_p = max(-(-max(ncb, 1) // pad_to) * pad_to, pad_to) if ncb > 0 else \
        max(1, min(pad_to, 8))
    return {
        "k_cmp": jnp.zeros((batch, ncb_p, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v_cmp": jnp.zeros((batch, ncb_p, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


# ---------------------------------------------------------------- routing
def routing(params, cfg: ModelConfig, q, k_cmp, v_cmp, positions, kv_len: int,
            ncb_valid=None):
    """The compression/routing launch (paper §5.1 'Routing Launch').

    q: (B, T, Hq, Dh); k_cmp/v_cmp: (B, NCB, Hkv, Dh); positions: (B, T).
    Returns (o_cmp (B,T,Hq,Dh), p_slc (B,T,Hkv,NSB), sel indices not included —
    Top-n is applied by the caller so exact/approx grouping policies can
    reinterpret the scores).
    """
    nsa = cfg.nsa
    B, T, Hq, Dh = q.shape
    Hkv, G = cfg.num_kv_heads, cfg.q_per_kv
    ncb = k_cmp.shape[1]
    qg = q.reshape(B, T, Hkv, G, Dh)
    scale = 1.0 / np.sqrt(Dh)
    logits = jnp.einsum("bthgd,bnhd->bthgn", qg.astype(jnp.float32),
                        k_cmp.astype(jnp.float32)) * scale
    vis = cmp_visible_mask(positions, ncb, nsa)                     # (B, T, NCB)
    if ncb_valid is not None:
        vis = vis & (jnp.arange(ncb)[None, None, :] < ncb_valid)
    logits = jnp.where(vis[:, :, None, None], logits, NEG_INF)
    p_cmp = jax.nn.softmax(logits, axis=-1)                          # (B,T,Hkv,G,NCB)
    p_cmp = jnp.where(vis[:, :, None, None], p_cmp, 0.0)             # all-masked rows -> 0
    o_cmp = jnp.einsum("bthgn,bnhd->bthgd", p_cmp, v_cmp.astype(jnp.float32))
    o_cmp = o_cmp.reshape(B, T, Hq, Dh)

    nsb = num_sel_blocks(kv_len, nsa)
    M = jnp.asarray(overlap_matrix(ncb, max(nsb, 1), nsa.cmp_block, nsa.cmp_stride,
                                   nsa.sel_block))
    # GQA-group share: sum scores over the G query heads of each KV group.
    p_grp = p_cmp.sum(axis=3)                                        # (B,T,Hkv,NCB)
    p_slc = jnp.einsum("bthn,ns->bths", p_grp, M)                    # (B,T,Hkv,NSB)
    return o_cmp, p_slc


def select_topn(p_slc, positions, kv_len: int, nsa: NSAConfig):
    """Top-n selection-block indices with mandatory initial + local blocks.

    p_slc: (B, T, Hkv, NSB); positions: (B, T).  Returns
    (indices (B,T,Hkv,n) int32 sorted ascending, valid (B,T,Hkv,n) bool).
    Invalid slots (block not yet causal / short prefix) carry index 0 and
    valid=False; downstream kernels mask them.
    """
    B, T, Hkv, NSB = p_slc.shape
    n = min(nsa.n_selected, NSB)
    starts = jnp.arange(NSB) * nsa.sel_block                         # block start pos
    causal = starts[None, None, :] <= positions[:, None][..., None] if positions.ndim == 1 \
        else starts[None, None, None, :] <= positions[..., None, None]
    # normalize shapes: causal (B, T, 1, NSB)
    causal = jnp.broadcast_to(causal.reshape(B, T, 1, NSB), (B, T, Hkv, NSB))
    # prefix-bounded: selection only routes over committed tokens
    causal &= (starts < kv_len)[None, None, None, :]

    scores = jnp.where(causal, p_slc, NEG_INF)
    # mandatory blocks: initial blocks + last n_local blocks at/preceding pos
    mand = jnp.zeros((B, T, Hkv, NSB), bool)
    if nsa.n_init_blocks > 0:
        mand = mand.at[..., : nsa.n_init_blocks].set(True)
    if nsa.n_local_blocks > 0:
        # last local blocks relative to each query position (within prefix)
        last_blk = jnp.minimum(positions[..., None], kv_len - 1) // nsa.sel_block  # (B,T,1)->? positions (B,T)
        last_blk = last_blk.reshape(B, T, 1, 1)
        off = jnp.arange(nsa.n_local_blocks).reshape(1, 1, 1, -1)
        loc = jnp.clip(last_blk - off, 0, NSB - 1)
        mand = mand | (jax.nn.one_hot(loc, NSB, dtype=jnp.int32).sum(axis=3) > 0)
    mand &= causal
    scores = jnp.where(mand, scores + 1e6, scores)

    top_vals, top_idx = jax.lax.top_k(scores, n)                      # (B,T,Hkv,n)
    valid = top_vals > NEG_INF / 2
    top_idx = jnp.where(valid, top_idx, 0)
    order = jnp.argsort(jnp.where(valid, top_idx, NSB + 1), axis=-1)
    top_idx = jnp.take_along_axis(top_idx, order, axis=-1)
    valid = jnp.take_along_axis(valid, order, axis=-1)
    return jax.lax.stop_gradient(top_idx), jax.lax.stop_gradient(valid)


# ---------------------------------------------------------------- gates
def gates(params, x, num_heads: int):
    g = jax.nn.sigmoid(x.astype(jnp.float32) @ params["w_gate"].astype(jnp.float32)
                       + params["b_gate"])
    B, T = x.shape[0], x.shape[1]
    return g.reshape(B, T, 3, num_heads)  # (B,T,3,Hq): order cmp, slc, win


# ---------------------------------------------------------------- train mode
def attend_train_nsa(params, cfg: ModelConfig, x, positions, chunk: int = 512):
    """Full-sequence NSA with exact semantics via masks (train / prefill).

    Returns (out (B,S,D), (k, v) full-sequence for cache building).
    Chunked over queries: per chunk the slc branch is a masked dense
    attention (selection mask at token granularity), cmp is an (S_c, NCB)
    attention, win an (S_c, S) banded attention.
    """
    nsa = cfg.nsa
    B, S, _ = x.shape
    Hq, Hkv, G, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    q, k, v = qkv(params, cfg, x, positions)
    k_cmp, v_cmp = compress_kv(params, k, v, nsa)
    ncb = k_cmp.shape[1]
    nsb = num_sel_blocks(S, nsa)
    g_all = gates(params, x, Hq)
    scale = 1.0 / np.sqrt(Dh)

    nchunk = max(1, S // chunk) if (chunk and S % chunk == 0) else 1
    Sc = S // nchunk

    def one_chunk(i):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * Sc, Sc, axis=1)
        qc, posc, gc = sl(q), sl(positions) if positions.ndim > 1 else jax.lax.dynamic_slice_in_dim(positions, i * Sc, Sc, 0), sl(g_all)
        posc2 = posc if posc.ndim == 2 else jnp.broadcast_to(posc[None], (B, Sc))
        # --- routing + cmp branch. Serve-consistent semantics: the query at
        # position p treats tokens < p as its committed prefix, so routing,
        # mandatory-local-block choice, and the slc token mask all use p-1 /
        # strict inequalities — exactly what nsa_verify_ref computes with
        # prefix_len == p (verified by tests/test_model_parity.py).
        o_cmp, p_slc = routing(params, cfg, qc, k_cmp, v_cmp, posc2 - 1, S)
        idx, idx_valid = select_topn(p_slc, posc2 - 1, S, nsa)        # (B,Sc,Hkv,n)
        # --- slc branch: token-granular mask from selected blocks
        blk_of_tok = jnp.arange(S) // nsa.sel_block                   # (S,)
        sel_mask = (idx[..., None] == blk_of_tok[None, None, None, None, :]) & \
            idx_valid[..., None]                                      # (B,Sc,Hkv,n,S)
        sel_mask = sel_mask.any(axis=3)                               # (B,Sc,Hkv,S)
        tok_strict = jnp.arange(S)[None, None, :] < posc2[..., None]   # slc: < p
        tok_causal = jnp.arange(S)[None, None, :] <= posc2[..., None]  # win: <= p
        sel_mask &= tok_strict[:, :, None, :]
        qg = qc.reshape(B, Sc, Hkv, G, Dh)
        logit_s = jnp.einsum("bthgd,bkhd->bhgtk", qg.astype(jnp.float32),
                             k.astype(jnp.float32)) * scale
        logit_s = jnp.where(sel_mask.transpose(0, 2, 1, 3)[:, :, None], logit_s, NEG_INF)
        p_s = jax.nn.softmax(logit_s, axis=-1)
        p_s = jnp.where(sel_mask.transpose(0, 2, 1, 3)[:, :, None], p_s, 0.0)
        o_slc = jnp.einsum("bhgtk,bkhd->bthgd", p_s, v.astype(jnp.float32)).reshape(B, Sc, Hq, Dh)
        # --- win branch
        win_mask = tok_causal & (jnp.arange(S)[None, None, :] > posc2[..., None] - nsa.window)
        logit_w = jnp.einsum("bthgd,bkhd->bhgtk", qg.astype(jnp.float32),
                             k.astype(jnp.float32)) * scale
        logit_w = jnp.where(win_mask[:, None, None], logit_w, NEG_INF)
        p_w = jax.nn.softmax(logit_w, axis=-1)
        o_win = jnp.einsum("bhgtk,bkhd->bthgd", p_w, v.astype(jnp.float32)).reshape(B, Sc, Hq, Dh)
        # --- gated combine
        out = (gc[:, :, 0, :, None] * o_cmp + gc[:, :, 1, :, None] * o_slc +
               gc[:, :, 2, :, None] * o_win)
        return out.astype(x.dtype)

    if nchunk > 1:
        _, outs = jax.lax.scan(lambda c, i: (c, one_chunk(i)), None, jnp.arange(nchunk))
        out = outs.swapaxes(0, 1).reshape(B, S, Hq, Dh)
    else:
        out = one_chunk(0)
    out = out.reshape(B, S, Hq * Dh) @ params["wo"]
    return out, (k, v)


def dyn_num_cmp_blocks(P, nsa: NSAConfig):
    """Traced version of num_cmp_blocks (P may be a traced int32)."""
    return jnp.where(P < nsa.cmp_block, 0, (P - nsa.cmp_block) // nsa.cmp_stride + 1)


# ---------------------------------------------------------------- verify (ref)
def gather_blocks(kv, idx, sel_block: int):
    """Gather selected blocks per (batch, query, kv-head) through the KV
    store: ``kv`` is a ``kvstore.KVView`` (dense or paged) or a raw
    ``{"k", "v"}`` dict. idx: (B, T, Hkv, n) block indices. Returns k_sel,
    v_sel: (B, T, Hkv, n, l', Dh).

    Out-of-range, negative, or (paged) unmapped block indices read an
    explicit zero page — never a silently clamped neighbor block. Callers
    additionally mask such positions out of the softmax (``nsa_verify_ref``
    adds ``tok_pos >= 0`` to the selection mask), so an adversarial index
    can neither read foreign KV nor shift attention mass.
    """
    return kvstore.as_view(kv).gather_blocks(idx, sel_block)


def nsa_verify_ref(params, cfg: ModelConfig, x, cache, cmp_cache, prefix_len,
                   positions, tree_mask, sel_idx=None, sel_valid=None,
                   return_kv: bool = True):
    """Reference NSA verification over gamma draft tokens (pure jnp oracle).

    x: (B, T, D) draft hidden states; positions (B, T) absolute; tree_mask
    (B, T, T).  ``sel_idx``/``sel_valid`` ((B,T,Hkv,n)) may be supplied by the
    SSV orchestrator (refresh/reuse + grouping policies); if None, fresh
    routing is computed (all-refresh, per-query exact behavior).

    cmp/slc branches attend the committed prefix only; the win branch covers
    the trailing window of the prefix plus tree-masked draft tokens —
    mirroring the paper's kernel semantics (sliding window stays exact).

    ``cache`` is the KV store handle: a ``kvstore.KVView`` (dense or paged —
    the slc gather and the win slice resolve through the page table when
    paged) or a raw ``{"k", "v"}`` dict (seed call sites).
    """
    nsa = cfg.nsa
    B, T, _ = x.shape
    Hq, Hkv, G, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    kv = kvstore.as_view(cache)
    q, k_new, v_new = qkv(params, cfg, x, positions)
    scale = 1.0 / np.sqrt(Dh)
    ncb_valid = dyn_num_cmp_blocks(prefix_len, nsa)
    g_all = gates(params, x, Hq)

    # ---- routing + cmp branch over committed prefix (max shapes + validity:
    # prefix_len may be a traced scalar in the jitted serve path)
    k_cmp, v_cmp = cmp_cache["k_cmp"], cmp_cache["v_cmp"]
    o_cmp, p_slc = routing(params, cfg, q, k_cmp, v_cmp, positions,
                           kv_len=kv.max_len, ncb_valid=ncb_valid)
    if sel_idx is None:
        sel_idx, sel_valid = select_topn(p_slc, positions, prefix_len, nsa)

    # ---- slc branch: gather + per-token causal/prefix mask
    k_sel, v_sel = gather_blocks(kv, sel_idx, nsa.sel_block)
    n = sel_idx.shape[-1]
    tok_pos = sel_idx[..., None] * nsa.sel_block + jnp.arange(nsa.sel_block)  # (B,T,Hkv,n,l')
    qg = q.reshape(B, T, Hkv, G, Dh)
    logit_sel = jnp.einsum("bthgd,bthnld->bthgnl", qg.astype(jnp.float32),
                           k_sel.astype(jnp.float32)) * scale
    # tok_pos >= 0 guards adversarial negative block indices (which would
    # otherwise pass the prefix/causal checks against a zero-filled gather)
    m_sel = (tok_pos >= 0) & (tok_pos < prefix_len) & \
        (tok_pos <= positions[:, :, None, None, None]) & sel_valid[..., None]
    logit_sel = jnp.where(m_sel[:, :, :, None], logit_sel, NEG_INF)
    flat = logit_sel.reshape(B, T, Hkv, G, n * nsa.sel_block)
    p_sel = jax.nn.softmax(flat, axis=-1)
    p_sel = jnp.where(m_sel[:, :, :, None].reshape(B, T, Hkv, 1, -1), p_sel, 0.0)
    o_slc = jnp.einsum("bthgk,bthkd->bthgd", p_sel,
                       v_sel.reshape(B, T, Hkv, n * nsa.sel_block, Dh).astype(jnp.float32))
    o_slc = o_slc.reshape(B, T, Hq, Dh)

    # ---- win branch: trailing-window *slice* of the prefix (keeps decode
    # sub-quadratic at 500K context) + tree-masked draft tokens
    S_max = kv.max_len
    W = min(nsa.window, S_max)
    win_start = jnp.clip(jnp.asarray(prefix_len) - W, 0, max(S_max - W, 0))
    k_win, v_win = kv.window(win_start, W)
    kpos = jnp.broadcast_to((win_start + jnp.arange(W)).reshape(1, 1, W), (B, T, W))
    pmask = (kpos < jnp.asarray(prefix_len)) & \
        (kpos > positions[..., None] - nsa.window) & (kpos <= positions[..., None])
    logit_p = jnp.einsum("bthgd,bkhd->bthgk", qg.astype(jnp.float32),
                         k_win.astype(jnp.float32)) * scale
    logit_p = jnp.where(pmask[:, :, None, None], logit_p, NEG_INF)
    dist = positions[:, :, None] - positions[:, None, :]
    dmask = tree_mask & (dist < nsa.window) & (dist >= 0)
    logit_d = jnp.einsum("bthgd,bkhd->bthgk", qg.astype(jnp.float32),
                         k_new.astype(jnp.float32)) * scale
    logit_d = jnp.where(dmask[:, :, None, None], logit_d, NEG_INF)
    logit_w = jnp.concatenate([logit_p, logit_d], axis=-1)
    p_w = jax.nn.softmax(logit_w, axis=-1)
    o_win = jnp.einsum("bthgk,bkhd->bthgd", p_w[..., :W],
                       v_win.astype(jnp.float32)) + \
        jnp.einsum("bthgk,bkhd->bthgd", p_w[..., W:], v_new.astype(jnp.float32))
    o_win = o_win.reshape(B, T, Hq, Dh)

    out = (g_all[:, :, 0, :, None] * o_cmp + g_all[:, :, 1, :, None] * o_slc +
           g_all[:, :, 2, :, None] * o_win).astype(x.dtype)
    out = out.reshape(B, T, Hq * Dh) @ params["wo"]
    if return_kv:
        return out, (k_new, v_new), (sel_idx, sel_valid)
    return out


def nsa_decode_ref(params, cfg: ModelConfig, x, cache, cmp_cache, length: int):
    """Single-token autoregressive NSA decode (the paper's 49-tok/s baseline
    shape). Thin wrapper: verify with T=1 and a trivial tree mask, then
    commit k/v through the store handle (dense write or page-table scatter);
    the caller updates the compression cache via update_cmp_cache.

    ``cache`` may be a raw ``{"k", "v"}`` dict or a ``kvstore.KVView``; the
    updated store comes back in the same form."""
    B = x.shape[0]
    positions = jnp.full((B, 1), length, jnp.int32)
    tree_mask = jnp.ones((B, 1, 1), bool)
    out, (k_new, v_new), _ = nsa_verify_ref(params, cfg, x, cache, cmp_cache,
                                            length, positions, tree_mask)
    kv = kvstore.as_view(cache)
    k, v = kv.write(k_new, v_new, length)
    if isinstance(cache, kvstore.KVView):
        return out, kvstore.KVView(k, v, kv.pages)
    return out, {"k": k, "v": v}
