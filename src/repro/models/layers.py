"""Core layer primitives: norms, rotary embeddings, activations, embeddings.

All layers are pure functions over parameter pytrees (dict-of-arrays), so the
whole model is trivially `jax.jit`/`pjit`-able and scan-able over stacked
layer parameters.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------- RMSNorm
def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(orig_dtype)


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float = 10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    # insert head axis
    angles = angles[..., None, :]  # (..., seq, 1, half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- activations
def squared_relu(x):
    return jnp.square(jax.nn.relu(x))


ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
    "squared_relu": squared_relu,
}

GATED = {"swiglu": jax.nn.silu, "geglu": jax.nn.gelu, "reglu": jax.nn.relu}


# ---------------------------------------------------------------- linear / ffn
def linear_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    return {"w": w.astype(dtype)}


def linear(params, x):
    return x @ params["w"]


def ffn_init(key, d_model: int, d_ff: int, activation: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in GATED:
        return {
            "w_gate": linear_init(k1, d_model, d_ff, dtype)["w"],
            "w_up": linear_init(k2, d_model, d_ff, dtype)["w"],
            "w_down": linear_init(k3, d_ff, d_model, dtype)["w"],
        }
    return {
        "w_up": linear_init(k1, d_model, d_ff, dtype)["w"],
        "w_down": linear_init(k2, d_ff, d_model, dtype)["w"],
    }


def ffn(params, x, activation: str):
    if activation in GATED:
        act = GATED[activation]
        h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        act = ACTIVATIONS[activation]
        h = act(x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------- embedding
def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    w = jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02
    return {"table": w.astype(dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    """x: (..., d) -> logits (..., V) using the (tied or separate) table."""
    return x @ params["table"].T


def lm_head_init(key, d_model: int, vocab: int, dtype=jnp.float32):
    return {"w": (jax.random.normal(key, (d_model, vocab), jnp.float32) / jnp.sqrt(d_model)).astype(dtype)}


def lm_head(params, x):
    return x @ params["w"]
