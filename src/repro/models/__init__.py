from repro.models import attention, layers, model, moe, nsa, recurrent  # noqa: F401
