from repro.config.base import (
    MeshConfig,
    ModelConfig,
    MoEConfig,
    NSAConfig,
    RecurrentConfig,
    ServeConfig,
    ShapeConfig,
    SHAPES,
    SSVConfig,
    TrainConfig,
)

__all__ = [
    "MeshConfig",
    "ModelConfig",
    "MoEConfig",
    "NSAConfig",
    "RecurrentConfig",
    "ServeConfig",
    "ShapeConfig",
    "SHAPES",
    "SSVConfig",
    "TrainConfig",
]
