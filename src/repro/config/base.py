"""Configuration dataclasses for the repro framework.

Everything in the system is driven by these configs: model architecture,
NSA sparse attention, SSV speculative verification, parallelism/mesh,
training, and serving. Configs are plain frozen dataclasses so they hash,
compare, and serialize trivially (msgpack/json via ``asdict``).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple


def _freeze(x):
    if isinstance(x, list):
        return tuple(_freeze(v) for v in x)
    return x


@dataclass(frozen=True)
class NSAConfig:
    """Native Sparse Attention hyperparameters (paper §2.2, §7 defaults)."""

    cmp_block: int = 32        # compression block length l
    cmp_stride: int = 16       # compression stride d
    sel_block: int = 64        # selection block size l'
    n_selected: int = 16       # Top-n selected blocks
    window: int = 512          # sliding-window size w
    # Mandatory blocks always included in the selection set (paper: initial +
    # local blocks give the s=3 overlap lower bound).
    n_init_blocks: int = 1
    n_local_blocks: int = 2

    def num_cmp_blocks(self, kv_len: int) -> int:
        if kv_len < self.cmp_block:
            return 0
        return (kv_len - self.cmp_block) // self.cmp_stride + 1

    def num_sel_blocks(self, kv_len: int) -> int:
        return max(0, -(-kv_len // self.sel_block))


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN."""

    num_experts: int = 8
    top_k: int = 2
    d_expert: int = 0          # expert hidden dim (0 -> use model d_ff)
    capacity_factor: float = 1.25
    num_shared_experts: int = 0
    router_jitter: float = 0.0
    # GShard-style dispatch group size: dispatch-einsum overhead scales as
    # group·cf/(3·d_ff), so thin-expert archs (qwen3-moe) use smaller groups.
    dispatch_group: int = 1024


@dataclass(frozen=True)
class RecurrentConfig:
    """Recurrent-block (RG-LRU / xLSTM) hyperparameters."""

    kind: str = "rglru"        # "rglru" | "mlstm" | "slstm"
    conv_width: int = 4        # temporal conv width before the recurrence
    state_dim: int = 0         # 0 -> d_model
    num_heads: int = 0         # 0 -> model heads


@dataclass(frozen=True)
class ModelConfig:
    """Generic decoder-only LM description covering the 10 assigned archs.

    ``block_pattern`` selects the per-layer block type; it is tiled to
    ``num_layers``. "attn" = attention+FFN block, "recur" = recurrent block,
    "moe" = attention + MoE-FFN block.
    """

    name: str = "model"
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0                      # 0 -> d_model // num_heads
    max_seq_len: int = 8192

    # Attention backend: "dense" | "nsa" | "swa" (sliding-window only)
    attention: str = "dense"
    # Train/prefill attention implementation: "chunked" materializes masked
    # score chunks (paper-faithful baseline); "online" is the flash-style
    # online-softmax XLA path (§Perf optimization — no score materialization)
    attention_impl: str = "chunked"
    window: int = 0                        # sliding window for attention="swa"
    qk_norm: bool = False
    rope_theta: float = 10000.0

    # FFN
    activation: str = "swiglu"             # swiglu | squared_relu | geglu | gelu
    moe: Optional[MoEConfig] = None

    # Layer pattern, e.g. ("recur", "recur", "attn") for recurrentgemma 1:2.
    block_pattern: Tuple[str, ...] = ("attn",)
    recurrent: Optional[RecurrentConfig] = None

    nsa: NSAConfig = field(default_factory=NSAConfig)

    # Modality frontend stub: "text" | "audio" | "vision"
    modality: str = "text"
    frontend_dim: int = 0                  # embedding dim of precomputed frames/patches

    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # Norm
    norm_eps: float = 1e-6

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        object.__setattr__(self, "block_pattern", _freeze(self.block_pattern))
        assert self.num_heads % self.num_kv_heads == 0, (
            f"num_heads={self.num_heads} not divisible by num_kv_heads={self.num_kv_heads}")

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.block_pattern
        reps = -(-self.num_layers // len(pat))
        return (pat * reps)[: self.num_layers]

    # ---- analytic parameter / FLOP accounting (used by roofline) ----
    def param_count(self) -> int:
        d, h = self.d_model, self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        embed = self.vocab_size * d
        out_head = 0 if self.tie_embeddings else self.vocab_size * d
        total = embed + out_head + d  # final norm
        kinds = self.layer_kinds()
        for kind in kinds:
            total += 2 * d  # two norms per block
            if kind in ("rglru", "mlstm", "slstm"):
                rc = self.recurrent
                sd = (rc.state_dim if rc else 0) or d
                cw = rc.conv_width if rc else 4
                if kind == "rglru":
                    total += 3 * d * sd + 2 * sd * sd + (cw + 1) * sd
                elif kind == "mlstm":
                    H = (rc.num_heads if rc else 0) or self.num_heads
                    total += 5 * d * d + 2 * d * H + H
                else:  # slstm
                    total += 9 * d * d + 4 * d
                total += self._ffn_params() if self.d_ff else 0
                continue
            # attention
            total += d * nq * h + 2 * d * nkv * h + nq * h * d
            if self.attention == "nsa":
                total += self.nsa.cmp_block * 2 + 3 * d  # pooling weights + gates
            if self.qk_norm:
                total += 2 * h
            total += self._ffn_params(moe=(kind == "moe"))
        return int(total)

    def _ffn_params(self, moe: bool = False) -> int:
        d = self.d_model
        gated = self.activation in ("swiglu", "geglu")
        per_ffn = (3 if gated else 2) * d * self.d_ff
        if moe and self.moe is not None:
            dff = self.moe.d_expert or self.d_ff
            per_exp = (3 if gated else 2) * d * dff
            return self.moe.num_experts * per_exp + d * self.moe.num_experts + \
                self.moe.num_shared_experts * per_ffn
        return per_ffn

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        dff = self.moe.d_expert or self.d_ff
        gated = self.activation in ("swiglu", "geglu")
        per_exp = (3 if gated else 2) * d * dff
        n_moe_layers = sum(1 for k in self.layer_kinds() if k == "moe")
        inactive = n_moe_layers * (self.moe.num_experts - self.moe.top_k) * per_exp
        return self.param_count() - int(inactive)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=str)


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: training or serving shapes."""

    name: str = "train_4k"
    seq_len: int = 4096
    global_batch: int = 256
    kind: str = "train"  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    def __post_init__(self):
        object.__setattr__(self, "shape", _freeze(self.shape))
        object.__setattr__(self, "axes", _freeze(self.axes))

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class SSVConfig:
    """Sparse speculative verification strategy tuple (θ_d, θ_s) + class P."""

    # θ_d — draft-side
    tree_depth: int = 4            # D
    tree_width: int = 2            # k (branching at each expansion)
    traversal: str = "bfs"         # "bfs" | "dfs"
    tree_budget: int = 0           # max nodes (0 -> full D,k tree)
    # θ_s — sparse-verification side
    group_size: int = 2            # coarsening factor C
    group_mode: str = "exact"      # "exact" | "approx" | "none"
    refresh_schedule: Tuple[int, ...] = ()  # layer indices that REUSE (empty -> all refresh)
    # P — precision class
    precision_class: str = "Strict"  # Strict | Reuse-only | Approx-only | Approx+Reuse

    def __post_init__(self):
        object.__setattr__(self, "refresh_schedule", _freeze(self.refresh_schedule))

    def num_draft_tokens(self) -> int:
        """Nodes in a full (D,k) tree, truncated to the budget."""
        n = 0
        level = 1
        for _ in range(self.tree_depth):
            level *= self.tree_width
            n += level
        if self.tree_budget:
            n = min(n, self.tree_budget)
        return n


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    learning_rate: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    micro_batches: int = 1
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    remat: bool = True
    grad_compression: str = "none"  # none | int8_ef
    log_every: int = 10


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_new_tokens: int = 128
    temperature: float = 0.0
    max_context: int = 16384
    ssv: SSVConfig = field(default_factory=SSVConfig)
    use_planner: bool = True
    # KV-cache store backend (core/kvstore.py): "dense" keeps per-request
    # (max_context, ...) buffers; "paged" shares a physical page pool across
    # requests through per-row page tables, so batch KV memory scales with
    # live tokens. kv_page_size=0 -> the model's nsa.sel_block (selected-
    # block gather becomes a page-table lookup); kv_num_pages=0 -> a pool
    # sized for worst-case occupancy (slots * max_context / page_size).
    kv_backend: str = "dense"
    kv_page_size: int = 0
    kv_num_pages: int = 0
