"""jit wrapper for the flash tree-verification kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash import kernel as K


def _pad_axis(x, axis: int, target: int):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, pad)
    return jnp.pad(x, w)


@functools.lru_cache(maxsize=128)
def _cached(key):
    return K.build_flash_verify(**dict(key))


def flash_verify(q, k_cache, v_cache, k_draft, v_draft, positions, prefix_len,
                 tree_mask, window: int = 0, interpret: bool = True):
    """q: (B,T,Hq,Dh) pre-scaled + rope'd. Returns (B,T,Hq,Dh) f32."""
    B, T, Hq, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    Gq = Hq // Hkv
    R = T * Gq
    TS = min(128, max(8, S))
    Sp = -(-S // TS) * TS
    Tp = max(8, -(-T // 8) * 8)

    q_l = q.reshape(B, T, Hkv, Gq, Dh).transpose(0, 2, 1, 3, 4).reshape(B, Hkv, R, Dh)
    k_p = _pad_axis(k_cache, 1, Sp)
    v_p = _pad_axis(v_cache, 1, Sp)
    kd = _pad_axis(k_draft, 1, Tp)
    vd = _pad_axis(v_draft, 1, Tp)
    dmask = tree_mask & (positions[:, :, None] >= positions[:, None, :])
    if window > 0:
        dmask &= (positions[:, :, None] - positions[:, None, :]) < window
    # row layout matches q_l: jnp.repeat along axis 1 maps draft row t to the
    # Gq consecutive rows [t*Gq, (t+1)*Gq)
    dm = _pad_axis(jnp.repeat(dmask, Gq, axis=1).astype(jnp.int32), 2, Tp)

    key = tuple(sorted(dict(B=B, Hkv=Hkv, R=R, Gq=Gq, Dh=Dh, Sp=Sp, Tp=Tp,
                            TS=TS, window=window, interpret=interpret).items()))
    call = _cached(key)
    s_scalar = jnp.stack([jnp.asarray(prefix_len, jnp.int32)])
    o = call(positions.astype(jnp.int32), s_scalar, q_l, k_p, v_p, kd, vd, dm)
    o = o.reshape(B, Hkv, T, Gq, Dh).transpose(0, 2, 1, 3, 4).reshape(B, T, Hq, Dh)
    return o
