"""Flash-attention tree-verification kernel (Pallas TPU).

Dense baseline for speculative verification: grid (B, Hkv, work) where work
walks KV-cache tiles then one draft tile; online softmax in VMEM scratch;
single write-back. Shares the accumulation structure of the fused NSA kernel
but with one branch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def make_kernel(*, R: int, Gq: int, Dh: int, TS: int, ST: int, Tp: int,
                window: int):
    TOTAL = ST + 1

    def kernel(s_pos, s_scalar, q_ref, k_ref, v_ref, kd_ref, vd_ref, dmask_ref,
               o_ref, acc_ref, l_ref, m_ref):
        b, h, w = (pl.program_id(i) for i in range(3))

        @pl.when(w == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            l_ref[...] = jnp.zeros_like(l_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG)

        q = q_ref[0, 0].astype(jnp.float32)                 # (R, Dh)
        pos_r = jnp.repeat(s_pos[b], Gq, total_repeat_length=R)
        prefix_len = s_scalar[0]

        def update(logits, mask, v):
            lm = jnp.where(mask, logits, NEG)
            m_new = jnp.maximum(m_ref[0], lm.max(-1))
            alpha = jnp.exp(m_ref[0] - m_new)
            p = jnp.exp(lm - m_new[:, None]) * mask
            l_ref[0] = l_ref[0] * alpha + p.sum(-1)
            acc_ref[0] = acc_ref[0] * alpha[:, None] + p @ v.astype(jnp.float32)
            m_ref[0] = m_new

        @pl.when(w < ST)
        def _cache():
            t = jnp.minimum(w, ST - 1)
            kpos = t * TS + jnp.arange(TS)
            mask = (kpos[None, :] < prefix_len) & (kpos[None, :] <= pos_r[:, None])
            if window > 0:
                mask &= kpos[None, :] > pos_r[:, None] - window
            update(q @ k_ref[0, :, 0].astype(jnp.float32).T, mask, v_ref[0, :, 0])

        @pl.when(w == ST)
        def _draft():
            mask = dmask_ref[0] > 0                          # (R, Tp)
            update(q @ kd_ref[0, :, 0].astype(jnp.float32).T, mask, vd_ref[0, :, 0])

        @pl.when(w == TOTAL - 1)
        def _fin():
            l = l_ref[0]
            o_ref[0, 0] = jnp.where(l[:, None] > 0,
                                    acc_ref[0] / jnp.maximum(l, 1e-30)[:, None],
                                    0.0).astype(o_ref.dtype)

    return kernel, TOTAL


def build_flash_verify(*, B: int, Hkv: int, R: int, Gq: int, Dh: int, Sp: int,
                       Tp: int, TS: int = 128, window: int = 0,
                       out_dtype=jnp.float32, interpret: bool = True):
    TS = min(TS, Sp)
    ST = max(1, Sp // TS)
    kernel, TOTAL = make_kernel(R=R, Gq=Gq, Dh=Dh, TS=TS, ST=ST, Tp=Tp,
                                window=window)
    grid = (B, Hkv, TOTAL)

    def cache_tile(b, h, w, *s):
        return (b, jnp.minimum(w, ST - 1), h, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, R, Dh), lambda b, h, w, *s: (b, h, 0, 0)),  # q
                pl.BlockSpec((1, TS, 1, Dh), cache_tile),                        # k
                pl.BlockSpec((1, TS, 1, Dh), cache_tile),                        # v
                pl.BlockSpec((1, Tp, 1, Dh), lambda b, h, w, *s: (b, 0, h, 0)),  # k_draft
                pl.BlockSpec((1, Tp, 1, Dh), lambda b, h, w, *s: (b, 0, h, 0)),  # v_draft
                pl.BlockSpec((1, R, Tp), lambda b, h, w, *s: (b, 0, 0)),         # dmask
            ],
            out_specs=pl.BlockSpec((1, 1, R, Dh), lambda b, h, w, *s: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, R, Dh), jnp.float32),
                pltpu.VMEM((1, R), jnp.float32),
                pltpu.VMEM((1, R), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, Dh), out_dtype),
        interpret=interpret,
    )
