"""Oracle for the dense tree-verification flash-attention kernel.

Dense verification (the paper's full-attention baseline): gamma tree queries
attend the committed prefix (optionally sliding-window limited) plus the
draft tokens under the tree mask. One softmax over [prefix | draft].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def ref_flash_verify(q, k_cache, v_cache, k_draft, v_draft, positions,
                     prefix_len, tree_mask, window: int = 0):
    """q: (B,T,Hq,Dh) pre-scaled; caches (B,S,Hkv,Dh); draft (B,T,Hkv,Dh);
    positions (B,T); tree_mask (B,T,T). Returns (B,T,Hq,Dh) f32."""
    B, T, Hq, Dh = q.shape
    S = k_cache.shape[1]
    Hkv = k_cache.shape[2]
    Gq = Hq // Hkv
    qg = q.reshape(B, T, Hkv, Gq, Dh).astype(jnp.float32)
    kpos = jnp.arange(S)[None, None, :]
    pmask = (kpos < prefix_len) & (kpos <= positions[..., None])
    if window > 0:
        pmask &= kpos > positions[..., None] - window
    lp = jnp.einsum("bthgd,bkhd->bthgk", qg, k_cache.astype(jnp.float32))
    lp = jnp.where(pmask[:, :, None, None], lp, NEG)
    dmask = tree_mask & (positions[:, :, None] >= positions[:, None, :])
    if window > 0:
        dmask &= (positions[:, :, None] - positions[:, None, :]) < window
    ld = jnp.einsum("bthgd,bkhd->bthgk", qg, k_draft.astype(jnp.float32))
    ld = jnp.where(dmask[:, :, None, None], ld, NEG)
    logits = jnp.concatenate([lp, ld], axis=-1)
    m = logits.max(-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p * jnp.concatenate([pmask[:, :, None, None].repeat(Hkv, 2).repeat(Gq, 3),
                             dmask[:, :, None, None].repeat(Hkv, 2).repeat(Gq, 3)], -1)
    l = p.sum(-1, keepdims=True)
    o = jnp.einsum("bthgk,bkhd->bthgd", p[..., :S], v_cache.astype(jnp.float32)) + \
        jnp.einsum("bthgk,bkhd->bthgd", p[..., S:], v_draft.astype(jnp.float32))
    o = jnp.where(l > 0, o / jnp.maximum(l, 1e-30), 0.0)
    return o.reshape(B, T, Hq, Dh)
