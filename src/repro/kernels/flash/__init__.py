from repro.kernels.flash import kernel, ops, ref  # noqa: F401
