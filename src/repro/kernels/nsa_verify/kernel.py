"""Fused SSV verification kernel (Pallas TPU).

TPU-native redesign of the paper's grouped-query NSA verification kernels
(§4, §5). One grid cell = (batch b, query-group g, kv-head h) — the Pallas
analogue of a GPU thread block; the 4th grid dimension walks a *work list*:

    [cmp tiles | merged selected blocks | window tiles | draft tile]

Each work step loads exactly one KV tile into VMEM (the other inputs' block
indices are frozen, so the TPU pipeline skips their re-fetch), computes
masked logits for the group's R = C·Gq query rows, and accumulates into the
branch's private online-softmax state held in VMEM scratch — the TPU version
of the paper's "per-branch normalization state in registers". The final work
step applies the learned gates and performs the single HBM write-back
("Unified Write-back" / "In-Register Aggregation").

Variants (all built by ``build_verify_call``):
  * full fusion (reuse layers):     include_cmp=True, combine=True
  * partial fusion (refresh layers): include_cmp=False + o_cmp input
  * branch-wise vanilla baseline:   one include_* flag at a time,
    combine=False (materializes the branch output — Figure 6(a) behavior)
  * exact vs approximate grouping is purely a matter of the merged-index /
    ownership inputs (built in ops.py) — the kernel is oblivious.

Selected blocks are gathered from HBM via scalar-prefetched block indices in
the BlockSpec index_map (the paged-attention pattern) — each unique merged
block is fetched exactly once per group, which is the paper's dedup-and-share
semantics on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _update(acc_ref, l_ref, m_ref, br: int, logits, mask, v_tile):
    """Online-softmax accumulation for one branch slot ``br``.
    logits: (R, K) f32; mask: (R, K) bool; v_tile: (K, Dh)."""
    lm = jnp.where(mask, logits, NEG)
    m_old = m_ref[br]                                    # (R,)
    m_new = jnp.maximum(m_old, lm.max(axis=-1))
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(lm - m_new[:, None]) * mask
    l_ref[br] = l_ref[br] * alpha + p.sum(axis=-1)
    acc_ref[br] = acc_ref[br] * alpha[:, None] + p @ v_tile.astype(jnp.float32)
    m_ref[br] = m_new


def make_kernel(*, C: int, Gq: int, Dh: int, M: int, TC: int, NCB_T: int,
                TW: int, WT: int, Tp: int, sel_block: int, cmp_block: int,
                cmp_stride: int, window: int, include_cmp: bool,
                include_sel: bool, include_win: bool, combine: bool,
                has_cmp_in: bool, paged: bool = False):
    R = C * Gq
    CMP_STEPS = NCB_T if include_cmp else 0
    SEL_STEPS = M if include_sel else 0
    WIN_STEPS = (WT + 1) if include_win else 0     # +1 = draft tile step
    TOTAL = max(CMP_STEPS + SEL_STEPS + WIN_STEPS, 1)

    def kernel(s_merged, s_mvalid, s_own, s_pos, s_scalar, *tail):
        # paged store: the scalar-prefetched page table drives the BlockSpec
        # index_map (logical block -> physical pool block); the kernel body
        # itself stays position-based on LOGICAL indices, so the masks below
        # are backend-oblivious.
        if paged:
            _s_pages, *tail = tail
        (q_ref, kcmp_ref, vcmp_ref, kblk_ref, vblk_ref, kwin_ref,
         vwin_ref, kdr_ref, vdr_ref, gates_ref, dmask_ref, *rest) = tail
        if has_cmp_in:
            ocmp_ref, o_ref, acc_ref, l_ref, m_ref = rest
        else:
            o_ref, acc_ref, l_ref, m_ref = rest
        b, g, h, w = (pl.program_id(i) for i in range(4))

        @pl.when(w == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            l_ref[...] = jnp.zeros_like(l_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG)

        q = q_ref[0, 0, 0].astype(jnp.float32)                     # (R, Dh)
        pos_c = s_pos[b, g]                                         # (C,) SMEM
        pos_r = jnp.repeat(pos_c, Gq, total_repeat_length=R)        # (R,)
        prefix_len = s_scalar[0]
        ncb_valid = s_scalar[1]
        win_start = s_scalar[2]

        if include_cmp:
            @pl.when(w < CMP_STEPS)
            def _cmp():
                t = jnp.minimum(w, NCB_T - 1)
                ids = t * TC + jnp.arange(TC)
                ends = ids * cmp_stride + cmp_block - 1
                mask = (ends[None, :] <= pos_r[:, None]) & (ids[None, :] < ncb_valid)
                kt = kcmp_ref[0, :, 0].astype(jnp.float32)          # (TC, Dh)
                _update(acc_ref, l_ref, m_ref, 0, q @ kt.T, mask, vcmp_ref[0, :, 0])

        if include_sel:
            @pl.when((w >= CMP_STEPS) & (w < CMP_STEPS + SEL_STEPS))
            def _sel():
                m = jnp.clip(w - CMP_STEPS, 0, M - 1)
                blk = s_merged[b, g, h, m]
                tok = blk * sel_block + jnp.arange(sel_block)
                ownrow = s_own[b, g, h, :, m]                       # (C,) int32
                own_r = jnp.repeat(ownrow, Gq, total_repeat_length=R) > 0
                mask = (tok[None, :] < prefix_len) & (tok[None, :] <= pos_r[:, None]) \
                    & (s_mvalid[b, g, h, m] > 0) & own_r[:, None]
                kt = kblk_ref[0, 0, :, 0].astype(jnp.float32)       # (l', Dh)
                _update(acc_ref, l_ref, m_ref, 1, q @ kt.T, mask, vblk_ref[0, 0, :, 0])

        if include_win:
            @pl.when((w >= CMP_STEPS + SEL_STEPS) & (w < TOTAL - 1))
            def _win():
                t = jnp.clip(w - CMP_STEPS - SEL_STEPS, 0, max(WT - 1, 0))
                kpos = win_start + t * TW + jnp.arange(TW)
                mask = (kpos[None, :] < prefix_len) & \
                    (kpos[None, :] > pos_r[:, None] - window) & \
                    (kpos[None, :] <= pos_r[:, None])
                kt = kwin_ref[0, :, 0].astype(jnp.float32)          # (TW, Dh)
                _update(acc_ref, l_ref, m_ref, 2, q @ kt.T, mask, vwin_ref[0, :, 0])

            @pl.when(w == TOTAL - 1)
            def _draft():
                kt = kdr_ref[0, :, 0].astype(jnp.float32)           # (Tp, Dh)
                mask = dmask_ref[0, 0] > 0                          # (R, Tp)
                _update(acc_ref, l_ref, m_ref, 2, q @ kt.T, mask, vdr_ref[0, :, 0])

        @pl.when(w == TOTAL - 1)
        def _finalize():
            gts = gates_ref[0, 0, 0].astype(jnp.float32)            # (R, 3)

            def safe(br):
                l = l_ref[br]
                return jnp.where(l[:, None] > 0,
                                 acc_ref[br] / jnp.maximum(l, 1e-30)[:, None], 0.0)

            if combine:
                o_cmp = (ocmp_ref[0, 0, 0].astype(jnp.float32) if has_cmp_in
                         else safe(0))
                out = gts[:, 0:1] * o_cmp + gts[:, 1:2] * safe(1) + gts[:, 2:3] * safe(2)
            else:
                out = safe(0 if include_cmp else (1 if include_sel else 2))
            o_ref[0, 0, 0] = out.astype(o_ref.dtype)

    return kernel, TOTAL, CMP_STEPS, SEL_STEPS


def build_verify_call(*, B: int, G: int, Hkv: int, C: int, Gq: int, Dh: int,
                      NSB: int, NCBp: int, M: int, Wp: int, Tp: int,
                      sel_block: int, cmp_block: int, cmp_stride: int,
                      window: int, TC: int = 128, TW: int = 128,
                      include_cmp: bool = True, include_sel: bool = True,
                      include_win: bool = True, combine: bool = True,
                      has_cmp_in: bool = False, out_dtype=jnp.float32,
                      interpret: bool = True, paged: bool = False,
                      blocks_per_page: int = 1, max_pages: int = 0):
    """Returns fn(s_merged, s_mvalid, s_own, s_pos, s_scalar[, s_pages],
    q_grp, k_cmp, v_cmp, k_blkd, v_blkd, k_win, v_win, k_draft, v_draft,
    gates_grp, dmask_grp[, o_cmp_grp]) -> o_grp (B, G, Hkv, R, Dh).

    ``paged``: ``s_merged`` carries LOGICAL selection-block indices and the
    extra ``s_pages`` (B, max_pages) scalar-prefetch input maps them to
    physical pool blocks inside the slc BlockSpec index_map — the
    paged-attention gather pattern; ``NSB`` is then the PHYSICAL block count
    of the (batch-broadcast) pool."""
    R = C * Gq
    TC = min(TC, NCBp)
    TW = min(TW, Wp)
    NCB_T = max(1, NCBp // TC)
    WT = max(1, Wp // TW)
    kernel, TOTAL, _, _ = make_kernel(
        C=C, Gq=Gq, Dh=Dh, M=M, TC=TC, NCB_T=NCB_T, TW=TW, WT=WT, Tp=Tp,
        sel_block=sel_block, cmp_block=cmp_block, cmp_stride=cmp_stride,
        window=window, include_cmp=include_cmp, include_sel=include_sel,
        include_win=include_win, combine=combine, has_cmp_in=has_cmp_in,
        paged=paged)

    grid = (B, G, Hkv, TOTAL)
    CMP_STEPS = NCB_T if include_cmp else 0
    SEL_STEPS = M if include_sel else 0

    def cmp_tile(b, g, h, w, *s):
        return (b, jnp.minimum(w, max(CMP_STEPS - 1, 0)) if include_cmp else 0, h, 0)

    def blk_tile(b, g, h, w, *s):
        s_merged = s[0]
        m = jnp.clip(w - CMP_STEPS, 0, M - 1)
        if paged:
            # logical -> physical: page-table lookup + sub-block offset.
            # Invalid / unmapped blocks were already devalidated (mvalid=0)
            # by the prep layer, so the clips only pick a safe fetch target.
            # The pool is shared across the batch (leading dim 1): batch
            # coordinate 0, row identity lives in the page table.
            blk = jnp.clip(s_merged[b, g, h, m], 0,
                           max_pages * blocks_per_page - 1)
            s_pages = s[5]
            phys = s_pages[b, blk // blocks_per_page]
            blk = jnp.clip(phys * blocks_per_page + blk % blocks_per_page,
                           0, NSB - 1)
            return (0, blk, 0, h, 0)
        blk = jnp.clip(s_merged[b, g, h, m], 0, NSB - 1)
        return (b, blk, 0, h, 0)

    def win_tile(b, g, h, w, *s):
        t = jnp.clip(w - CMP_STEPS - SEL_STEPS, 0, max(WT - 1, 0))
        return (b, t, h, 0)

    in_specs = [
        pl.BlockSpec((1, 1, 1, R, Dh), lambda b, g, h, w, *s: (b, g, h, 0, 0)),   # q
        pl.BlockSpec((1, TC, 1, Dh), cmp_tile),                                    # k_cmp
        pl.BlockSpec((1, TC, 1, Dh), cmp_tile),                                    # v_cmp
        pl.BlockSpec((1, 1, sel_block, 1, Dh), blk_tile),                          # k blocks
        pl.BlockSpec((1, 1, sel_block, 1, Dh), blk_tile),                          # v blocks
        pl.BlockSpec((1, TW, 1, Dh), win_tile),                                    # k_win
        pl.BlockSpec((1, TW, 1, Dh), win_tile),                                    # v_win
        pl.BlockSpec((1, Tp, 1, Dh), lambda b, g, h, w, *s: (b, 0, h, 0)),         # k_draft
        pl.BlockSpec((1, Tp, 1, Dh), lambda b, g, h, w, *s: (b, 0, h, 0)),         # v_draft
        pl.BlockSpec((1, 1, 1, R, 3), lambda b, g, h, w, *s: (b, g, h, 0, 0)),     # gates
        pl.BlockSpec((1, 1, R, Tp), lambda b, g, h, w, *s: (b, g, 0, 0)),          # dmask
    ]
    if has_cmp_in:
        in_specs.append(pl.BlockSpec((1, 1, 1, R, Dh),
                                     lambda b, g, h, w, *s: (b, g, h, 0, 0)))      # o_cmp

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=6 if paged else 5,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, 1, R, Dh),
                                   lambda b, g, h, w, *s: (b, g, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((3, R, Dh), jnp.float32),   # acc
                pltpu.VMEM((3, R), jnp.float32),       # l
                pltpu.VMEM((3, R), jnp.float32),       # m
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, G, Hkv, R, Dh), out_dtype),
        interpret=interpret,
    )
