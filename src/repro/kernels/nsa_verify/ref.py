"""Pure-jnp oracle for the fused SSV verification kernel.

Kernel-level contract (projections/RoPE happen outside; the kernel sees
ready tensors). Per (batch b, kv-head h, query-group g of C adjacent
flattened-tree queries) the fused kernel computes the three NSA branches with
independent online-softmax states and gated aggregation:

  cmp — queries vs compressed KV (visibility: block fully before query pos,
        block index < ncb_valid)
  slc — queries vs the group's merged selected blocks (exact: ownership mask
        restores per-query semantics; approx: all rows own all merged blocks)
  win — queries vs [win_start, win_start+W) trailing prefix slice (per-row
        sliding window) plus the draft tokens (tree mask ∧ window distance)

Output: out[row] = g_cmp·o_cmp + g_slc·o_slc + g_win·o_win per query row
(row = (c, gqa-subhead)). Branches with zero visible tokens contribute 0.

This file is the oracle the Pallas kernel is tested against for every shape/
dtype in tests/test_kernels_nsa_verify.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG = -1e30


def _branch_attend(logits, mask, v):
    """logits: (R, K) f32; mask: (R, K) bool; v: (K, Dh). Returns (R, Dh)
    softmax attention with fully-masked rows -> 0."""
    logits = jnp.where(mask, logits, NEG)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m) * mask
    l = p.sum(-1, keepdims=True)
    o = p @ v.astype(jnp.float32)
    return jnp.where(l > 0, o / jnp.maximum(l, 1e-30), 0.0)


def ref_verify_group(q, k_cache, v_cache, k_cmp, v_cmp, k_draft, v_draft,
                     merged_idx, own, positions, group_qidx, prefix_len,
                     ncb_valid, tree_mask, gates, *, sel_block: int,
                     cmp_block: int, cmp_stride: int, window: int,
                     include_cmp: bool = True, o_cmp_in=None):
    """One (b, h, g) instance.

    q:          (C, Gq, Dh)  query rows (C queries x GQA subheads), pre-scaled
    k_cache:    (S, Dh), v_cache: (S, Dh)   this kv-head's committed cache
    k_cmp:      (NCB, Dh), v_cmp: (NCB, Dh)
    k_draft:    (T, Dh), v_draft: (T, Dh)   draft-token K/V for this head
    merged_idx: (M,) int32 merged selected blocks (-1 padding)
    own:        (C, M) bool ownership (exact) or all-True (approx)
    positions:  (T,) absolute positions of all draft queries
    group_qidx: (C,) indices of this group's queries into the T flattened
    prefix_len, ncb_valid: scalars
    tree_mask:  (T, T) bool
    gates:      (C, Gq, 3) f32 (cmp, slc, win)
    o_cmp_in:   (C, Gq, Dh) — partial-fusion mode (include_cmp=False) passes
                the routing launch's compressed-branch output instead.
    Returns (C, Gq, Dh) f32.
    """
    C, Gq, Dh = q.shape
    R = C * Gq
    qf = q.reshape(R, Dh).astype(jnp.float32)
    pos_c = positions[group_qidx]                                  # (C,)
    pos_r = jnp.repeat(pos_c, Gq)                                  # (R,)

    # ---- cmp branch
    if include_cmp:
        NCB = k_cmp.shape[0]
        ends = jnp.arange(NCB) * cmp_stride + cmp_block - 1
        cmask = (ends[None, :] <= pos_r[:, None]) & \
            (jnp.arange(NCB)[None, :] < ncb_valid)
        logits = qf @ k_cmp.astype(jnp.float32).T
        o_cmp = _branch_attend(logits, cmask, v_cmp)
    else:
        o_cmp = o_cmp_in.reshape(R, Dh).astype(jnp.float32)

    # ---- slc branch over merged blocks
    M = merged_idx.shape[0]
    blk = jnp.clip(merged_idx, 0, None)
    tok = blk[:, None] * sel_block + jnp.arange(sel_block)[None, :]  # (M, l')
    S = k_cache.shape[0]
    tokc = jnp.clip(tok, 0, S - 1)
    k_sel = k_cache[tokc.reshape(-1)]                               # (M*l', Dh)
    v_sel = v_cache[tokc.reshape(-1)]
    own_r = jnp.repeat(own, Gq, axis=0)                             # (C,M) -> (R,M)
    valid_tok = jnp.repeat(merged_idx >= 0, sel_block)[None, :]
    own_tok = jnp.repeat(own_r, sel_block, axis=1)                  # (R, M*l')
    smask = (tokc.reshape(-1)[None, :] < prefix_len) & \
        (tokc.reshape(-1)[None, :] <= pos_r[:, None]) & valid_tok & own_tok
    logits = qf @ k_sel.astype(jnp.float32).T
    o_slc = _branch_attend(logits, smask, v_sel)

    # ---- win branch: trailing prefix slice + draft tokens
    W = min(window, S)
    win_start = jnp.clip(prefix_len - W, 0, max(S - W, 0))
    k_win = jax.lax.dynamic_slice_in_dim(k_cache, win_start, W, axis=0)
    v_win = jax.lax.dynamic_slice_in_dim(v_cache, win_start, W, axis=0)
    kpos = win_start + jnp.arange(W)
    wmask = (kpos[None, :] < prefix_len) & (kpos[None, :] > pos_r[:, None] - window) & \
        (kpos[None, :] <= pos_r[:, None])
    dist = pos_r[:, None] - positions[None, :]
    tmask_rows = tree_mask[group_qidx]                              # (C, T)
    dmask = jnp.repeat(tmask_rows, Gq, axis=0) & (dist < window) & (dist >= 0)
    logits_w = jnp.concatenate([qf @ k_win.astype(jnp.float32).T,
                                qf @ k_draft.astype(jnp.float32).T], axis=-1)
    mask_w = jnp.concatenate([wmask, dmask], axis=-1)
    o_win = _branch_attend(logits_w, mask_w,
                           jnp.concatenate([v_win, v_draft], axis=0))

    g = gates.reshape(R, 3).astype(jnp.float32)
    out = g[:, 0:1] * o_cmp + g[:, 1:2] * o_slc + g[:, 2:3] * o_win
    return out.reshape(C, Gq, Dh)


def ref_verify_batched(q, k_cache, v_cache, k_cmp, v_cmp, k_draft, v_draft,
                       merged_idx, own, positions, prefix_len, ncb_valid,
                       tree_mask, gates, *, group_size: int, sel_block: int,
                       cmp_block: int, cmp_stride: int, window: int,
                       include_cmp: bool = True, o_cmp_in=None):
    """Full-batch oracle.

    q:          (B, T, Hq, Dh) pre-scaled, rope'd
    k_cache:    (B, S, Hkv, Dh) (+v)
    k_cmp:      (B, NCB, Hkv, Dh) (+v)
    k_draft:    (B, T, Hkv, Dh) (+v)
    merged_idx: (B, G, Hkv, M); own: (B, G, Hkv, C, M)
    positions:  (B, T); tree_mask: (B, T, T); gates: (B, T, 3, Hq)
    o_cmp_in:   (B, T, Hq, Dh) for partial-fusion mode
    Returns (B, T, Hq, Dh) f32.
    """
    B, T, Hq, Dh = q.shape
    Hkv = k_cache.shape[2]
    Gq = Hq // Hkv
    C = group_size
    G = -(-T // C)
    qidx = np.minimum(np.arange(G * C).reshape(G, C), T - 1)
    out = jnp.zeros((B, T, Hq, Dh), jnp.float32)
    for b in range(B):
        for h in range(Hkv):
            for g in range(G):
                gq = qidx[g]
                qg = q[b][gq][:, h * Gq:(h + 1) * Gq]              # (C, Gq, Dh)
                gates_g = gates[b][gq][:, :, h * Gq:(h + 1) * Gq].transpose(0, 2, 1)
                o = ref_verify_group(
                    qg, k_cache[b, :, h], v_cache[b, :, h], k_cmp[b, :, h],
                    v_cmp[b, :, h], k_draft[b, :, h], v_draft[b, :, h],
                    merged_idx[b, g, h], own[b, g, h], positions[b],
                    jnp.asarray(gq), prefix_len, ncb_valid, tree_mask[b],
                    gates_g, sel_block=sel_block, cmp_block=cmp_block,
                    cmp_stride=cmp_stride, window=window,
                    include_cmp=include_cmp,
                    o_cmp_in=None if o_cmp_in is None else
                    o_cmp_in[b][gq][:, h * Gq:(h + 1) * Gq])
                seen = set()
                for ci, cq in enumerate(gq):
                    # only the FIRST occurrence of a (tail-padded duplicated)
                    # query is authoritative — padded replicas carry empty
                    # ownership and would dilute the slc branch
                    if int(cq) in seen:
                        continue
                    seen.add(int(cq))
                    out = out.at[b, cq, h * Gq:(h + 1) * Gq].set(o[ci])
    return out
