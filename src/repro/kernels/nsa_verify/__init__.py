from repro.kernels.nsa_verify import kernel, ops, ref  # noqa: F401
