"""jit-ready wrappers around the fused SSV verification kernel.

``nsa_verify_fused`` is the public entry: it takes model-level tensors plus
the SSV grouping strategy, builds the merged-schedule (exact) or shared-index
(approx) layouts + ownership masks, pads everything to kernel tiles, invokes
the Pallas kernel, and un-groups the output.

All layout preparation is pure jnp (fuses into the surrounding XLA graph) —
the TPU-native replacement for the paper's in-kernel warp sort/dedup (see
DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import NSAConfig
from repro.core import kvstore, overlap
from repro.kernels.nsa_verify import kernel as K


def _pad_axis(x, axis: int, target: int):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# Mixed-bucket serving multiplies the distinct kernel shapes in flight: each
# execution group's (strategy, group-size) pair contributes its own (T, C, M,
# ...) tuple per layer mode, so the seed maxsize of 128 could thrash once a
# profile's worth of strategies serve concurrently. 1024 entries keep every
# realistic shape set resident; hit/miss counters are surfaced through
# ``verify_call_cache_info`` into the engines' kernel-cache metrics.
@functools.lru_cache(maxsize=1024)
def _cached_call(key):
    return K.build_verify_call(**dict(key))


def verify_call_cache_info():
    """Hit/miss/size counters of the fused-kernel build cache (process-wide —
    every engine in the process shares one kernel cache)."""
    return _cached_call.cache_info()


def prepare_groups(q, gates, sel_idx, sel_valid, positions, C: int, mode: str,
                   n_sel: int):
    """Group queries and build merged/ownership layouts.

    q: (B,T,Hq,Dh) -> q_grp (B,G,Hkv,R,Dh); gates (B,T,3,Hq) ->
    (B,G,Hkv,R,3); merged (B,G,Hkv,M); mvalid; own (B,G,Hkv,C,M);
    pos_grp (B,G,C).
    """
    B, T, Hq, Dh = q.shape
    Hkv = sel_idx.shape[2]
    Gq = Hq // Hkv
    qmap, _ = overlap.group_queries(T, C)
    G = qmap.shape[0]
    gi = jnp.asarray(qmap)                                          # (G, C)

    qx = q.reshape(B, T, Hkv, Gq, Dh)[:, gi]                        # (B,G,C,Hkv,Gq,Dh)
    q_grp = qx.transpose(0, 1, 3, 2, 4, 5).reshape(B, G, Hkv, C * Gq, Dh)
    gx = gates.transpose(0, 1, 3, 2).reshape(B, T, Hkv, Gq, 3)[:, gi]
    gates_grp = gx.transpose(0, 1, 3, 2, 4, 5).reshape(B, G, Hkv, C * Gq, 3)
    pos_grp = positions[:, gi]                                      # (B, G, C)

    if mode == "approx":
        idx2, val2 = overlap.shared_index(sel_idx, sel_valid, positions, C)
        # per group, merged list = the representative's n blocks (every member
        # of the group carries identical values — take member 0's)
        merged = idx2[:, gi[:, 0]]                                  # (B,G,Hkv,n)
        merged = jnp.where(val2[:, gi[:, 0]], merged, -1)
        mvalid = val2[:, gi[:, 0]]
        own = jnp.ones((B, G, Hkv, C, merged.shape[-1]), jnp.int32)
        merged = merged.astype(jnp.int32)
        return q_grp, gates_grp, merged, mvalid.astype(jnp.int32), own, pos_grp, gi
    # exact merged schedule
    merged, own, mvalid = overlap.merged_schedule(sel_idx, sel_valid, C)
    merged = jnp.where(mvalid, merged, -1).astype(jnp.int32)
    return q_grp, gates_grp, merged, mvalid.astype(jnp.int32), \
        own.astype(jnp.int32), pos_grp, gi


def nsa_verify_fused(q, k_cache, v_cache, k_cmp, v_cmp, k_draft, v_draft,
                     sel_idx, sel_valid, positions, prefix_len, ncb_valid,
                     tree_mask, gates, nsa: NSAConfig, C: int = 2,
                     mode: str = "exact", include_cmp: bool = True,
                     o_cmp_in=None, combine: bool = True,
                     include_sel: bool = True, include_win: bool = True,
                     interpret: bool = True, page_table=None):
    """Fused grouped-query NSA verification (see kernel.py docstring).

    q: (B,T,Hq,Dh) — ALREADY rope'd and scaled by 1/sqrt(Dh).
    Returns (B, T, Hq, Dh) f32.

    ``page_table`` (B, max_pages) int32 switches the KV inputs to the paged
    store: ``k_cache``/``v_cache`` are then the shared page pool
    (P, page_size, Hkv, Dh). Selected-block indices are resolved through the
    page table in the jnp prep layer (fusing into the surrounding XLA graph,
    like the merged-schedule build): logical block -> physical pool block
    for the slc gather index_map, and the win branch's trailing slice is
    gathered from the row's pages. Unmapped / out-of-range blocks are
    masked, not clamped. The kernel itself is oblivious to paging — it sees
    pre-resolved physical block indices (ref parity:
    tests/test_kernels_nsa_verify.py::test_fused_paged_matches_dense).
    """
    B, T, Hq, Dh = q.shape
    lb = nsa.sel_block
    paged = page_table is not None
    if paged:
        ps = k_cache.shape[1]
        S = page_table.shape[1] * ps
        Hkv = k_cache.shape[2]
    else:
        S = k_cache.shape[1]
        Hkv = k_cache.shape[2]
    Gq = Hq // Hkv

    q_grp, gates_grp, merged, mvalid, own, pos_grp, gi = prepare_groups(
        q, gates, sel_idx, sel_valid, positions, C, mode, nsa.n_selected)
    G = q_grp.shape[1]
    M = merged.shape[-1]
    R = C * Gq

    if paged:
        # pages tile selection blocks (page_size % sel_block == 0), so the
        # BlockSpec index_map resolves a LOGICAL merged block to a physical
        # pool block via the scalar-prefetched page table; ``merged`` stays
        # logical (the kernel's prefix/causal masks are position-based).
        # Unmapped pages only get their validity bit cleared here.
        m = ps // lb
        P = k_cache.shape[0]
        NSB = P * m                                      # physical blocks
        nsb_logical = page_table.shape[1] * m
        lp = jnp.clip(jnp.where(merged >= 0, merged, 0) // m, 0,
                      page_table.shape[1] - 1)
        phys_pg = jnp.take_along_axis(
            page_table, lp.reshape(B, -1), axis=1).reshape(lp.shape)
        mvalid = jnp.where((merged >= 0) & (phys_pg >= 0), mvalid, 0)
        merged = jnp.where(mvalid > 0, merged, -1)
        # the pool stays SHARED (leading dim 1, never broadcast-materialized
        # to B copies — that would forfeit paging's memory win); the paged
        # blk index_map pins the pool's batch coordinate to 0 and the page
        # table supplies the per-row physical block
        k_blkd = k_cache.reshape(1, P * m, lb, Hkv, Dh)
        v_blkd = v_cache.reshape(1, P * m, lb, Hkv, Dh)
    else:
        # cache reshaped into selection blocks for the gather index_map
        Sp = -(-S // lb) * lb
        NSB = Sp // lb
        nsb_logical = NSB
        k_blkd = _pad_axis(k_cache, 1, Sp).reshape(B, NSB, lb, Hkv, Dh)
        v_blkd = _pad_axis(v_cache, 1, Sp).reshape(B, NSB, lb, Hkv, Dh)

    # compressed cache padded to the cmp tile
    NCB = k_cmp.shape[1]
    TC = min(128, max(8, NCB))
    NCBp = -(-NCB // TC) * TC
    k_cmp_p = _pad_axis(k_cmp, 1, NCBp)
    v_cmp_p = _pad_axis(v_cmp, 1, NCBp)

    # window slice (paged: gathered from the row's pages by the store view)
    W = min(nsa.window, S)
    win_start = jnp.clip(jnp.asarray(prefix_len) - W, 0, max(S - W, 0))
    kv_view = kvstore.KVView(k_cache, v_cache, page_table)
    k_win, v_win = kv_view.window(win_start, W)
    TW = min(128, max(8, W))
    Wp = -(-W // TW) * TW
    k_win = _pad_axis(k_win, 1, Wp)
    v_win = _pad_axis(v_win, 1, Wp)

    # draft tile + combined draft mask (tree ∧ window ∧ causal ∧ valid)
    Tp = max(8, -(-T // 8) * 8)
    k_draft_p = _pad_axis(k_draft, 1, Tp)
    v_draft_p = _pad_axis(v_draft, 1, Tp)
    dist = positions[:, :, None] - positions[:, None, :]            # (B,T,T)
    dmask = tree_mask & (dist < nsa.window) & (dist >= 0)
    dmask_g = dmask[:, gi]                                          # (B,G,C,T)
    dmask_g = jnp.repeat(dmask_g, Gq, axis=2)                       # (B,G,R,T)
    dmask_g = _pad_axis(dmask_g.astype(jnp.int32), 3, Tp)

    s_scalar = jnp.stack([jnp.asarray(prefix_len, jnp.int32),
                          jnp.asarray(ncb_valid, jnp.int32),
                          win_start.astype(jnp.int32),
                          jnp.asarray(T, jnp.int32)])

    key = tuple(sorted(dict(
        B=B, G=G, Hkv=Hkv, C=C, Gq=Gq, Dh=Dh, NSB=NSB, NCBp=NCBp, M=M,
        Wp=Wp, Tp=Tp, sel_block=lb, cmp_block=nsa.cmp_block,
        cmp_stride=nsa.cmp_stride, window=nsa.window, TC=TC, TW=TW,
        include_cmp=include_cmp, include_sel=include_sel,
        include_win=include_win, combine=combine,
        has_cmp_in=o_cmp_in is not None, interpret=interpret,
        paged=paged, blocks_per_page=(ps // lb if paged else 1),
        max_pages=(page_table.shape[1] if paged else 0)).items()))
    call = _cached_call(key)

    merged_c = jnp.clip(merged, 0, nsb_logical - 1)
    args = [merged_c, mvalid, own, pos_grp.astype(jnp.int32), s_scalar]
    if paged:
        args.append(page_table.astype(jnp.int32))
    args += [q_grp, k_cmp_p, v_cmp_p, k_blkd, v_blkd, k_win, v_win,
             k_draft_p, v_draft_p, gates_grp, dmask_g]
    if o_cmp_in is not None:
        oc = o_cmp_in.reshape(B, T, Hkv, Gq, Dh)[:, gi]
        oc = oc.transpose(0, 1, 3, 2, 4, 5).reshape(B, G, Hkv, R, Dh)
        args.append(oc)
    o_grp = call(*args)                                             # (B,G,Hkv,R,Dh)

    o = o_grp.reshape(B, G, Hkv, C, Gq, Dh).transpose(0, 1, 3, 2, 4, 5)
    o = o.reshape(B, G * C, Hkv * Gq, Dh)[:, :T]
    return o


def kernel_launch_count(nsa: NSAConfig, mode: str) -> int:
    """Structural launch-count metric used by the benchmarks: vanilla NSA =
    3 branch kernels + routing; refresh = routing + fused downstream; reuse =
    1 fully fused kernel."""
    return {"vanilla": 4, "refresh": 2, "reuse": 1}[mode]


def nsa_verify_kernel_layer(params, cfg, x, cache, cmp_cache, prefix_len,
                            positions, tree_mask, sel_idx=None, sel_valid=None,
                            C: int = 2, mode: str = "exact",
                            reuse: bool = False, interpret: bool = True,
                            page_table=None):
    """Full NSA verification of one layer through the Pallas kernels — the
    kernel-backed counterpart of ``models.nsa.nsa_verify_ref``.

    reuse=False (refresh layer): routing launch (compressed attention +
      selection scores, XLA) -> Top-n indices -> partially fused downstream
      kernel (slc + win + gated aggregation, include_cmp=False).
    reuse=True: indices are inherited (``sel_idx`` required) -> single fully
      fused kernel computing all three branches.

    ``cache`` is a raw ``{"k", "v"}`` dict, or the paged store's pool with
    ``page_table`` supplied (equivalently a ``kvstore.KVView``).

    Returns (out (B,T,D), (k_new, v_new), (sel_idx, sel_valid)).
    """
    import numpy as _np

    from repro.models import attention as attn_lib
    from repro.models import nsa as nsa_lib

    if isinstance(cache, kvstore.KVView):
        kv = cache
    else:
        kv = kvstore.KVView(cache["k"], cache["v"], page_table)
    nsa = cfg.nsa
    B, T, _ = x.shape
    Hq, Dh = cfg.num_heads, cfg.head_dim
    q, k_new, v_new = attn_lib.qkv(params, cfg, x, positions)
    q_s = q / _np.sqrt(Dh)
    g_all = nsa_lib.gates(params, x, Hq)                           # (B,T,3,Hq)
    ncb_valid = nsa_lib.dyn_num_cmp_blocks(prefix_len, nsa)

    if reuse:
        assert sel_idx is not None, "reuse layers inherit indices"
        out = nsa_verify_fused(
            q_s, kv.k, kv.v, cmp_cache["k_cmp"], cmp_cache["v_cmp"],
            k_new, v_new, sel_idx, sel_valid, positions, prefix_len, ncb_valid,
            tree_mask, g_all, nsa, C=C, mode=mode, include_cmp=True,
            interpret=interpret, page_table=kv.pages)
    else:
        o_cmp, p_slc = nsa_lib.routing(params, cfg, q, cmp_cache["k_cmp"],
                                       cmp_cache["v_cmp"], positions,
                                       kv_len=kv.max_len,
                                       ncb_valid=ncb_valid)
        sel_idx, sel_valid = nsa_lib.select_topn(p_slc, positions, prefix_len, nsa)
        out = nsa_verify_fused(
            q_s, kv.k, kv.v, cmp_cache["k_cmp"], cmp_cache["v_cmp"],
            k_new, v_new, sel_idx, sel_valid, positions, prefix_len, ncb_valid,
            tree_mask, g_all, nsa, C=C, mode=mode, include_cmp=False,
            o_cmp_in=o_cmp, interpret=interpret, page_table=kv.pages)
    out = out.astype(x.dtype).reshape(B, T, Hq * Dh) @ params["wo"]
    return out, (k_new, v_new), (sel_idx, sel_valid)


def nsa_verify_vanilla_layer(params, cfg, x, cache, cmp_cache, prefix_len,
                             positions, tree_mask, interpret: bool = True):
    """Vanilla-NSA baseline execution (paper Fig. 6(a)): per-branch kernels
    with intermediate branch-output materialization, no grouping (C=1), fresh
    index construction — the reference point the SSV speedups are measured
    against."""
    import numpy as _np

    from repro.models import attention as attn_lib
    from repro.models import nsa as nsa_lib

    nsa = cfg.nsa
    B, T, _ = x.shape
    Hq, Dh = cfg.num_heads, cfg.head_dim
    q, k_new, v_new = attn_lib.qkv(params, cfg, x, positions)
    q_s = q / _np.sqrt(Dh)
    g_all = nsa_lib.gates(params, x, Hq)
    ncb_valid = nsa_lib.dyn_num_cmp_blocks(prefix_len, nsa)
    o_cmp, p_slc = nsa_lib.routing(params, cfg, q, cmp_cache["k_cmp"],
                                   cmp_cache["v_cmp"], positions,
                                   kv_len=cache["k"].shape[1], ncb_valid=ncb_valid)
    sel_idx, sel_valid = nsa_lib.select_topn(p_slc, positions, prefix_len, nsa)
    common = dict(interpret=interpret, C=1, mode="exact", combine=False)
    o_slc = nsa_verify_fused(q_s, cache["k"], cache["v"], cmp_cache["k_cmp"],
                             cmp_cache["v_cmp"], k_new, v_new, sel_idx, sel_valid,
                             positions, prefix_len, ncb_valid, tree_mask, g_all,
                             nsa, include_cmp=False, include_win=False, **common)
    o_win = nsa_verify_fused(q_s, cache["k"], cache["v"], cmp_cache["k_cmp"],
                             cmp_cache["v_cmp"], k_new, v_new, sel_idx, sel_valid,
                             positions, prefix_len, ncb_valid, tree_mask, g_all,
                             nsa, include_cmp=False, include_sel=False, **common)
    # branch outputs materialize (HBM round-trip), gated combine in XLA
    out = g_all[:, :, 0][..., None] * o_cmp.astype(jnp.float32) + \
        g_all[:, :, 1][..., None] * o_slc + g_all[:, :, 2][..., None] * o_win
    out = out.astype(x.dtype).reshape(B, T, Hq * Dh) @ params["wo"]
    return out, (k_new, v_new), (sel_idx, sel_valid)
