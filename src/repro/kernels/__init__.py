"""Pallas TPU kernels for the compute hot-spots the paper optimizes:

nsa_verify — fused grouped-query NSA verification (full fusion for reuse
             layers, partial fusion for refresh layers, branch-wise vanilla
             baseline; exact merged-schedule and approximate shared-index
             grouping) + pure-jnp oracle.
flash      — dense tree-verification flash attention (the full-attention
             baseline + draft-model attention) + oracle.
routing    — refresh-layer "Routing Launch" (paper §5.1): fused
             compressed-branch attention + selection-score mapping (one
             normalization yields both) + oracle.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling, scalar-prefetch
block gathers) and are validated on CPU with interpret=True.
"""
from repro.kernels import flash, nsa_verify, routing  # noqa: F401
