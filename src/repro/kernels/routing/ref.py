"""Oracle for the routing kernel (refresh-layer launch 1, paper §5.1):
compressed-branch attention + selection-block scores in one pass.

Given queries and the compressed KV cache, produce
  o_cmp  — the compression branch's attention output, and
  p_slc  — GQA-group-shared selection-block scores: the compressed-attention
           probability mass mapped through the (cmp-block → selection-block)
           fractional overlap matrix (NSA eq. 9 generalized).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def ref_routing(q, k_cmp, v_cmp, M, positions, ncb_valid, *, cmp_block: int,
                cmp_stride: int):
    """q: (B,T,Hq,Dh) pre-scaled; k_cmp/v_cmp: (B,NCB,Hkv,Dh);
    M: (NCB, NSB) overlap matrix; positions (B,T); ncb_valid scalar.
    Returns (o_cmp (B,T,Hq,Dh) f32, p_slc (B,T,Hkv,NSB) f32)."""
    B, T, Hq, Dh = q.shape
    NCB, Hkv = k_cmp.shape[1], k_cmp.shape[2]
    Gq = Hq // Hkv
    qg = q.reshape(B, T, Hkv, Gq, Dh).astype(jnp.float32)
    ends = jnp.arange(NCB) * cmp_stride + cmp_block - 1
    vis = (ends[None, None, :] <= positions[..., None]) & \
        (jnp.arange(NCB)[None, None, :] < ncb_valid)                # (B,T,NCB)
    logits = jnp.einsum("bthgd,bkhd->bthgk", qg, k_cmp.astype(jnp.float32))
    logits = jnp.where(vis[:, :, None, None], logits, NEG)
    m = logits.max(-1, keepdims=True)
    e = jnp.exp(logits - m) * vis[:, :, None, None]
    l = e.sum(-1, keepdims=True)
    p = jnp.where(l > 0, e / jnp.maximum(l, 1e-30), 0.0)
    o_cmp = jnp.einsum("bthgk,bkhd->bthgd", p, v_cmp.astype(jnp.float32))
    p_slc = jnp.einsum("bthgk,ks->bths", p, M.astype(jnp.float32))
    return o_cmp.reshape(B, T, Hq, Dh), p_slc
