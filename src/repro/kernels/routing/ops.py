"""jit wrapper for the fused routing kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import NSAConfig
from repro.kernels.routing import kernel as K
from repro.models.nsa import num_sel_blocks, overlap_matrix


def _pad_axis(x, axis, target):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, pad)
    return jnp.pad(x, w)


@functools.lru_cache(maxsize=64)
def _cached(key):
    return K.build_routing_call(**dict(key))


def routing_fused(q, k_cmp, v_cmp, positions, ncb_valid, nsa: NSAConfig,
                  kv_len: int, interpret: bool = True):
    """q: (B,T,Hq,Dh) pre-scaled + rope'd; k_cmp/v_cmp (B,NCB,Hkv,Dh).
    Returns (o_cmp (B,T,Hq,Dh) f32, p_slc (B,T,Hkv,NSB) f32)."""
    B, T, Hq, Dh = q.shape
    NCB, Hkv = k_cmp.shape[1], k_cmp.shape[2]
    Gq = Hq // Hkv
    R = T * Gq
    NSB = num_sel_blocks(kv_len, nsa)
    TC = min(128, max(8, NCB))
    NCBp = -(-NCB // TC) * TC
    M = jnp.asarray(overlap_matrix(NCBp, NSB, nsa.cmp_block, nsa.cmp_stride,
                                   nsa.sel_block))
    q_l = q.reshape(B, T, Hkv, Gq, Dh).transpose(0, 2, 1, 3, 4).reshape(
        B, Hkv, R, Dh)
    key = tuple(sorted(dict(B=B, Hkv=Hkv, R=R, Gq=Gq, Dh=Dh, NCBp=NCBp,
                            NSB=NSB, TC=TC, cmp_block=nsa.cmp_block,
                            cmp_stride=nsa.cmp_stride,
                            interpret=interpret).items()))
    call = _cached(key)
    s_scalar = jnp.stack([jnp.asarray(ncb_valid, jnp.int32)])
    o, p_slc = call(positions.astype(jnp.int32), s_scalar, q_l,
                    _pad_axis(k_cmp, 1, NCBp), _pad_axis(v_cmp, 1, NCBp), M)
    o = o.reshape(B, Hkv, T, Gq, Dh).transpose(0, 2, 1, 3, 4).reshape(
        B, T, Hq, Dh)
    return o, p_slc.transpose(0, 2, 1, 3)
