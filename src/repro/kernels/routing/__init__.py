from repro.kernels.routing import kernel, ops, ref  # noqa: F401
