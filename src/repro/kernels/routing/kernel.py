"""Routing kernel (Pallas TPU): the refresh-layer "Routing Launch" of
paper §5.1 — fused compressed-branch attention + selection-score mapping.

Grid (B, Hkv, cmp-tiles): each step loads one (TC, Dh) compressed-KV tile
and the matching (TC, NSB) slice of the static overlap matrix into VMEM,
updates the per-row online-softmax state AND the selection-score accumulator
(kept in the same rescaled space as the attention accumulator, so one
normalization at the finalize step yields both the branch output and the
exact selection scores). This fuses what the vanilla implementation runs as
two passes (attention, then score mapping) with an intermediate
materialization of the (T, NCB) probability matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def make_kernel(*, R: int, Gq: int, Dh: int, TC: int, NT: int, NSB: int,
                cmp_block: int, cmp_stride: int):
    T = R // Gq

    def kernel(s_pos, s_scalar, q_ref, k_ref, v_ref, m_ref_in, o_ref, p_ref,
               acc_ref, l_ref, m_ref, s_ref):
        b, h, t = (pl.program_id(i) for i in range(3))

        @pl.when(t == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            l_ref[...] = jnp.zeros_like(l_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG)
            s_ref[...] = jnp.zeros_like(s_ref)

        q = q_ref[0, 0].astype(jnp.float32)                  # (R, Dh)
        pos_r = jnp.repeat(s_pos[b], Gq, total_repeat_length=R)
        ncb_valid = s_scalar[0]
        ids = t * TC + jnp.arange(TC)
        ends = ids * cmp_stride + cmp_block - 1
        vis = (ends[None, :] <= pos_r[:, None]) & (ids[None, :] < ncb_valid)

        k = k_ref[0, :, 0].astype(jnp.float32)               # (TC, Dh)
        logits = jnp.where(vis, q @ k.T, NEG)
        m_new = jnp.maximum(m_ref[...], logits.max(-1))
        alpha = jnp.exp(m_ref[...] - m_new)
        p = jnp.exp(logits - m_new[:, None]) * vis
        l_ref[...] = l_ref[...] * alpha + p.sum(-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            p @ v_ref[0, :, 0].astype(jnp.float32)
        s_ref[...] = s_ref[...] * alpha[:, None] + \
            p @ m_ref_in[...].astype(jnp.float32)            # (R, NSB)
        m_ref[...] = m_new

        @pl.when(t == NT - 1)
        def _fin():
            l = jnp.maximum(l_ref[...], 1e-30)
            nz = l_ref[...] > 0
            o_ref[0, 0] = jnp.where(nz[:, None], acc_ref[...] / l[:, None],
                                    0.0).astype(o_ref.dtype)
            ps = jnp.where(nz[:, None], s_ref[...] / l[:, None], 0.0)
            # GQA share: sum the Gq query heads of this kv group
            p_ref[0, 0] = ps.reshape(T, Gq, NSB).sum(1).astype(p_ref.dtype)

    return kernel


def build_routing_call(*, B: int, Hkv: int, R: int, Gq: int, Dh: int,
                       NCBp: int, NSB: int, TC: int, cmp_block: int,
                       cmp_stride: int, interpret: bool = True):
    TC = min(TC, NCBp)
    NT = max(1, NCBp // TC)
    T = R // Gq
    kernel = make_kernel(R=R, Gq=Gq, Dh=Dh, TC=TC, NT=NT, NSB=NSB,
                         cmp_block=cmp_block, cmp_stride=cmp_stride)

    def tile(b, h, t, *s):
        return (b, jnp.minimum(t, NT - 1), h, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hkv, NT),
            in_specs=[
                pl.BlockSpec((1, 1, R, Dh), lambda b, h, t, *s: (b, h, 0, 0)),   # q
                pl.BlockSpec((1, TC, 1, Dh), tile),                               # k_cmp
                pl.BlockSpec((1, TC, 1, Dh), tile),                               # v_cmp
                pl.BlockSpec((TC, NSB), lambda b, h, t, *s:
                             (jnp.minimum(t, NT - 1), 0)),                        # M tile
            ],
            out_specs=[
                pl.BlockSpec((1, 1, R, Dh), lambda b, h, t, *s: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, T, NSB), lambda b, h, t, *s: (b, h, 0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((R, Dh), jnp.float32),
                pltpu.VMEM((R,), jnp.float32),
                pltpu.VMEM((R,), jnp.float32),
                pltpu.VMEM((R, NSB), jnp.float32),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((B, Hkv, R, Dh), jnp.float32),
                   jax.ShapeDtypeStruct((B, Hkv, T, NSB), jnp.float32)],
        interpret=interpret,
    )
