"""xlstm-125m [ssm]: sLSTM + mLSTM blocks (attention-free).
[arXiv:2405.04517; unverified]  12L d_model=768 4H d_ff=0 vocab=50304.
NSA/SSV selection inapplicable (no KV cache); speculative verification runs
via recurrent state replay — DESIGN.md §Arch-applicability."""
from repro.config import ModelConfig, NSAConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4, d_ff=0,
    vocab_size=50304, max_seq_len=524800,
    block_pattern=("mlstm", "slstm"),
    recurrent=RecurrentConfig(kind="mlstm", num_heads=4),
    nsa=NSAConfig(), dtype="bfloat16",
)

DRYRUN = {"long_500k": {"native": True}}
