"""nemotron-4-340b [dense]: GQA + squared-ReLU FFN. [arXiv:2402.16819;
unverified]  96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000."""
from repro.config import ModelConfig, NSAConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8, d_ff=73728,
    vocab_size=256000, max_seq_len=524800,
    attention="dense", activation="squared_relu",
    nsa=NSAConfig(), dtype="bfloat16",
)

DRYRUN = {"train_4k": {"micro_batches": 16},
          "long_500k": {"nsa": True}}  # dense 500K decode skipped; NSA unlocks it
