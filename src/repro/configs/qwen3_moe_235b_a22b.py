"""qwen3-moe-235b-a22b [moe]: 128 experts top-8, thin experts.
[hf:Qwen/Qwen3-30B-A3B; hf]  94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936. Small dispatch group keeps one-hot dispatch overhead bounded
for the thin d_ff (see models/moe.py)."""
from repro.config import ModelConfig, MoEConfig, NSAConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, d_ff=1536,
    vocab_size=151936, max_seq_len=524800,
    attention="dense", activation="swiglu", qk_norm=True,
    block_pattern=("moe",),
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536, dispatch_group=256),
    nsa=NSAConfig(), dtype="bfloat16",
)

DRYRUN = {"train_4k": {"micro_batches": 8}, "long_500k": {"nsa": True}}
