"""mixtral-8x22b [moe]: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]  56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768."""
from repro.config import ModelConfig, MoEConfig, NSAConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8, d_ff=16384,
    vocab_size=32768, max_seq_len=524800,
    attention="swa", window=4096, activation="swiglu",
    block_pattern=("moe",),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=16384, dispatch_group=1024),
    nsa=NSAConfig(), dtype="bfloat16",
)

DRYRUN = {"train_4k": {"micro_batches": 4}, "long_500k": {"nsa": True}}
