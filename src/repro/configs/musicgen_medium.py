"""musicgen-medium [audio]: decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]  48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048. The EnCodec/text-conditioning frontend is a STUB: input_specs()
provides 64 precomputed conditioning frames (frontend_dim=768)."""
from repro.config import ModelConfig, NSAConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24, d_ff=6144,
    vocab_size=2048, max_seq_len=524800,
    attention="dense", activation="gelu",
    modality="audio", frontend_dim=768,
    nsa=NSAConfig(), dtype="bfloat16",
)

FRONTEND_LEN = 64
DRYRUN = {"long_500k": {"nsa": True}}
