"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1:2 pattern.
[arXiv:2402.19427; unverified]  38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000. Local attention window 2048 (Griffin). NSA/SSV applicability:
partial — see DESIGN.md §Arch-applicability."""
from repro.config import ModelConfig, NSAConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1, d_ff=12288,
    vocab_size=256000, max_seq_len=524800,
    attention="swa", window=2048, activation="geglu",
    block_pattern=("rglru", "rglru", "attn"),
    recurrent=RecurrentConfig(kind="rglru", conv_width=4),
    nsa=NSAConfig(), dtype="bfloat16",
)

# long-context decode is native (recurrence + windowed attention)
DRYRUN = {"train_4k": {"micro_batches": 4}, "long_500k": {"native": True}}
