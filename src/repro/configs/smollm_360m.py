"""smollm-360m [dense]: llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]
32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152. NOTE: 15 heads does not
divide the 16-way model axis — GSPMD pads (documented in DESIGN.md)."""
from repro.config import ModelConfig, NSAConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5, d_ff=2560,
    vocab_size=49152, max_seq_len=524800,
    attention="dense", activation="swiglu",
    nsa=NSAConfig(), dtype="bfloat16",
)

DRYRUN = {"long_500k": {"nsa": True}}
