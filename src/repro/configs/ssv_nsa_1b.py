"""The paper's 1B-class NSA target model (§7: 32 query heads, 8 KV heads,
head dim 64; NSA l=32 d=16 l'=64 n=16 w=512), llama3-1B-like backbone."""
from repro.config import ModelConfig, NSAConfig

CONFIG = ModelConfig(
    name="ssv-nsa-1b",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8, head_dim=64,
    d_ff=6144, vocab_size=32768, max_seq_len=65536,
    attention="nsa", activation="swiglu",
    nsa=NSAConfig(cmp_block=32, cmp_stride=16, sel_block=64, n_selected=16,
                  window=512),
    dtype="bfloat16",
)

DRYRUN = {}
