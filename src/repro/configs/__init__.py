"""Architecture registry: ``--arch <id>`` resolution.

Each module exposes CONFIG (the exact assigned full-scale config), optional
DRYRUN overrides (per-shape micro-batching / NSA-mode notes), optional
FRONTEND_LEN (modality stub prefix length), and ``reduced()`` below builds
the CI smoke-test variant of any arch (same family, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

from repro.config import ModelConfig, MoEConfig, NSAConfig

ARCH_IDS = (
    "recurrentgemma-9b", "nemotron-4-340b", "smollm-360m", "granite-20b",
    "qwen3-8b", "mixtral-8x22b", "qwen3-moe-235b-a22b", "xlstm-125m",
    "musicgen-medium", "pixtral-12b", "ssv-nsa-1b", "ssv-nsa-8b",
)

ASSIGNED = ARCH_IDS[:10]


def _module(arch_id: str):
    return importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_"))


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _module(arch_id).CONFIG


def dryrun_overrides(arch_id: str) -> Dict:
    return getattr(_module(arch_id), "DRYRUN", {})


def frontend_len(arch_id: str) -> int:
    return getattr(_module(arch_id), "FRONTEND_LEN", 0)


def nsa_variant(cfg: ModelConfig) -> ModelConfig:
    """The SSV-serving variant of an architecture: attention layers replaced
    by NSA (paper §7.2, 'attention layers replaced by NSA-based sparse
    verification'). No-op for attention-free archs."""
    if all(k in ("rglru", "mlstm", "slstm") for k in cfg.layer_kinds()):
        return cfg
    return dataclasses.replace(cfg, attention="nsa", name=cfg.name + "-nsa")


def reduced(arch_id: str, *, vocab: int = 512, layers: Optional[int] = None,
            d_model: int = 0, seq_cap: int = 2048) -> ModelConfig:
    """CI-scale variant preserving the family (pattern, attention kind, MoE
    topology, modality) with tiny dims."""
    cfg = get_config(arch_id)
    pat = cfg.block_pattern
    L = layers if layers is not None else max(2, 2 * len(pat))
    L = max(L, len(pat))
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    d = d_model or 64 * heads
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(num_experts=min(cfg.moe.num_experts, 4),
                        top_k=min(cfg.moe.top_k, 2),
                        d_expert=128, dispatch_group=64)
    rec = cfg.recurrent
    if rec is not None:
        rec = dataclasses.replace(rec, num_heads=min(rec.num_heads or heads, heads))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=L, d_model=d, num_heads=heads, num_kv_heads=kv,
        head_dim=0,
        d_ff=0 if cfg.d_ff == 0 else 2 * d,
        vocab_size=vocab, max_seq_len=seq_cap,
        window=min(cfg.window, 64) if cfg.window else 0,
        moe=moe, recurrent=rec,
        frontend_dim=32 if cfg.frontend_dim else 0,
        nsa=NSAConfig(cmp_block=8, cmp_stride=4, sel_block=16, n_selected=4,
                      window=32),
        dtype="float32",
    )
