"""granite-20b [dense]: llama-arch code model, MQA. [arXiv:2405.04324; hf]
52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152."""
from repro.config import ModelConfig, NSAConfig

CONFIG = ModelConfig(
    name="granite-20b",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1, d_ff=24576,
    vocab_size=49152, max_seq_len=524800,
    attention="dense", activation="gelu",
    nsa=NSAConfig(), dtype="bfloat16",
)

DRYRUN = {"train_4k": {"micro_batches": 2}, "long_500k": {"nsa": True}}
