"""pixtral-12b [vlm]: pixtral-ViT frontend (STUB) + mistral-nemo decoder.
[hf:mistralai/Pixtral-12B-2409; unverified]  40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072. input_specs() provides 256 precomputed patch
embeddings (frontend_dim=1024)."""
from repro.config import ModelConfig, NSAConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, d_ff=14336,
    vocab_size=131072, max_seq_len=524800,
    attention="dense", activation="swiglu",
    modality="vision", frontend_dim=1024,
    nsa=NSAConfig(), dtype="bfloat16",
)

FRONTEND_LEN = 256
DRYRUN = {"long_500k": {"nsa": True}}
