"""The paper's 8B-class NSA target (Llama3-8B backbone with attention layers
replaced by NSA — §7.2)."""
from repro.config import ModelConfig, NSAConfig

CONFIG = ModelConfig(
    name="ssv-nsa-8b",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, max_seq_len=65536,
    attention="nsa", activation="swiglu",
    nsa=NSAConfig(cmp_block=32, cmp_stride=16, sel_block=64, n_selected=16,
                  window=512),
    dtype="bfloat16",
)

DRYRUN = {}
