"""qwen3-8b [dense]: qk_norm + GQA. [hf:Qwen/Qwen3-8B; hf]
36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936."""
from repro.config import ModelConfig, NSAConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, d_ff=12288,
    vocab_size=151936, max_seq_len=524800,
    attention="dense", activation="swiglu", qk_norm=True,
    nsa=NSAConfig(), dtype="bfloat16",
)

DRYRUN = {"long_500k": {"nsa": True}}
