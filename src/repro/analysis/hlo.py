"""Optimized-HLO text analyzer with while-loop trip-count correction.

``compiled.cost_analysis()`` counts every instruction ONCE — scan bodies are
not multiplied by their trip counts (verified empirically on the CPU
backend), so a layer-scanned model under-reports FLOPs by ~L×. This module
re-walks the optimized HLO text:

  * computations are parsed into op lists;
  * the call graph is traversed from ENTRY with a multiplier; ``while`` ops
    multiply by their ``backend_config known_trip_count`` (present in XLA's
    optimized HLO); fusions/calls recurse at the same multiplier;
  * dot FLOPs are computed from operand shapes + contracting dims;
  * collective wire bytes are accumulated per collective type with
    replica-group-aware ring scaling;
  * HBM-bytes proxy: sum of (operand + result) bytes of non-trivial ops at
    top fusion granularity (XLA's fusion model keeps intermediates on-chip).

This powers the §Roofline terms in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def parse_shape(s: str) -> Tuple[str, Tuple[int, ...]]:
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return "opaque", ()
    dtype = m.group(1)
    dims = tuple(int(x) for x in m.group(2).split(",") if x) if m.group(2) else ()
    return dtype, dims


def shape_bytes(s: str) -> int:
    dtype, dims = parse_shape(s)
    n = 1
    for d in dims:
        n *= d
    return n * DTYPE_BYTES.get(dtype, 4)


def _tuple_shapes(text: str) -> List[str]:
    """Split a (possibly tuple) result type into element type strings."""
    text = text.strip()
    if text.startswith("("):
        depth = 0
        parts, cur = [], []
        for ch in text[1:-1]:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append("".join(cur))
                cur = []
            else:
                cur.append(ch)
        if cur:
            parts.append("".join(cur))
        return parts
    return [text]


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    operand_names: List[str]
    attrs: str
    called: List[str]           # computation names referenced


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symbols: Dict[str, str] = dataclasses.field(default_factory=dict)

    def operand_types(self, op: Op) -> List[str]:
        return [self.symbols.get(n, "opaque[]") for n in op.operand_names]


# result type: either a tuple (balanced at depth 1 — layouts use braces, not
# parens) or a single shape with optional layout annotation
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*?)\)(.*)$")


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)  # strip /*index=N*/ tuple comments
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped and "=" not in \
                stripped.split("->")[0]:
            head = stripped.split("(")[0].strip()
            is_entry = head.startswith("ENTRY")
            name = head.replace("ENTRY", "").strip().lstrip("%")
            if name:
                cur = Computation(name=name, ops=[])
                comps[name] = cur
                if is_entry:
                    entry = name
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, kind, operands, attrs = m.groups()
        operand_names = [x.lstrip("%") for x in re.findall(
            r"%?([\w\.\-]+)", operands)
            if not re.match(r"^[a-z0-9]+\[", x)]
        # simpler robust operand-name parse: split top-level commas, last token
        operand_names = []
        depth = 0
        curtok = []
        for ch in operands + ",":
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            if ch == "," and depth == 0:
                tok = "".join(curtok).strip()
                if tok:
                    operand_names.append(tok.split()[-1].lstrip("%"))
                curtok = []
            else:
                curtok.append(ch)
        called = re.findall(r"(?:to_apply|calls|body|condition)=%?([\w\.\-]+)",
                            attrs)
        m2 = re.search(r"branch_computations=\{([^}]*)\}", attrs)
        if m2:
            called.extend(x.strip().lstrip("%") for x in m2.group(1).split(","))
        cur.ops.append(Op(name=name, kind=kind, result_type=rtype,
                          operand_names=operand_names, attrs=attrs,
                          called=called))
        cur.symbols[name] = rtype
    return comps, entry


def _trip_count(op: Op) -> int:
    m = re.search(r'known_trip_count[^0-9]*"?n"?\s*[:=]\s*"?(\d+)"?', op.attrs)
    return int(m.group(1)) if m else 1


def _dot_flops(op: Op, comp: "Computation") -> float:
    """2 * prod(result dims) * contracted size (batch dims handled by result)."""
    _, rdims = parse_shape(op.result_type if not op.result_type.startswith("(")
                           else _tuple_shapes(op.result_type)[0])
    optypes = comp.operand_types(op)
    if not optypes:
        return 0.0
    _, ldims = parse_shape(optypes[0])
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    csize = 1
    if m and ldims:
        for d in m.group(1).split(","):
            if d:
                csize *= ldims[int(d)]
    rsize = 1
    for d in rdims:
        rsize *= d
    return 2.0 * rsize * csize


def _conv_flops(op: Op, comp: "Computation") -> float:
    # rough: 2 * output size * (kernel spatial * in_channels)
    _, rdims = parse_shape(op.result_type)
    optypes = comp.operand_types(op)
    if len(optypes) < 2:
        return 0.0
    _, kdims = parse_shape(optypes[1])
    rsize = 1
    for d in rdims:
        rsize *= d
    ksize = 1
    for d in kdims[:-1]:
        ksize *= d
    return 2.0 * rsize * ksize


def _group_size(op: Op, total: int) -> int:
    """Parse replica_groups=[G,S]<=[N] (iota) or explicit {{..},..} groups."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", op.attrs)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return total


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    collective_wire_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {c: 0 for c in COLLECTIVES})
    per_op_flops: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.collective_wire_bytes.values())


def analyze(text: str, num_devices: int = 1) -> Analysis:
    """Walk the optimized HLO from ENTRY, multiplying while bodies by their
    known trip counts. All quantities are PER-MODULE (i.e. per device for an
    SPMD module)."""
    comps, entry = parse_module(text)
    out = Analysis()
    seen_stack: List[str] = []

    def visit(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen_stack:
            return
        seen_stack.append(comp_name)
        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                tc = _trip_count(op)
                body = [c for c in op.called if "region" in c or "body" in c.lower()
                        or c in comps]
                # body/condition both referenced; visit each with multiplier
                m_body = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                m_cond = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                if m_body:
                    visit(m_body.group(1), mult * tc)
                if m_cond:
                    visit(m_cond.group(1), mult * tc)
                continue
            if kind in ("fusion", "call", "conditional", "map", "reduce",
                        "reduce-window", "scatter", "sort", "custom-call",
                        "select-and-scatter", "all-reduce"):
                for c in op.called:
                    visit(c, mult)
            if kind == "dot":
                f = _dot_flops(op, comp) * mult
                out.flops += f
                out.per_op_flops[f"{comp_name}/{op.name}"] = f
            elif kind == "convolution":
                out.flops += _conv_flops(op, comp) * mult
            base = kind.split("-start")[0]
            if base in COLLECTIVES:
                size = sum(shape_bytes(t) for t in comp.operand_types(op))
                if base == "all-gather":
                    size = sum(shape_bytes(t) for t in _tuple_shapes(op.result_type))
                g = _group_size(op, num_devices)
                wire = {
                    "all-gather": size * (g - 1) / max(g, 1),
                    "all-reduce": 2.0 * size * (g - 1) / max(g, 1),
                    "reduce-scatter": size * (g - 1) / max(g, 1),
                    "all-to-all": size * (g - 1) / max(g, 1),
                    "collective-permute": float(size),
                }[base]
                out.collective_bytes[base] += size * mult
                out.collective_wire_bytes[base] += wire * mult
                out.collective_counts[base] += int(mult)
            # HBM proxy: top-level data movement with op-aware semantics —
            # slicing ops touch only the slice, in-place updates (dus, and
            # fusions wrapping a dus into an aliased buffer) touch only the
            # update window, broadcasts write only their result.
            optypes = comp.operand_types(op)
            rbytes = sum(shape_bytes(t) for t in _tuple_shapes(op.result_type))
            io_bytes = None
            if kind in ("dynamic-slice", "gather"):
                io_bytes = 2.0 * rbytes              # read slice + write result
            elif kind in ("dynamic-update-slice",):
                upd = shape_bytes(optypes[1]) if len(optypes) > 1 else rbytes
                io_bytes = 2.0 * upd                 # read + write the window
            elif kind in ("scatter",):
                upd = shape_bytes(optypes[-1]) if optypes else rbytes
                io_bytes = 2.0 * upd
            elif kind in ("broadcast", "iota", "constant"):
                io_bytes = rbytes
            elif kind == "fusion":
                io_bytes = sum(shape_bytes(t) for t in optypes) + rbytes
                inner = comps.get(op.called[0]) if op.called else None
                if inner is not None:
                    dus_upd = [shape_bytes(inner.symbols.get(o.operand_names[1],
                                                             "opaque[]"))
                               for o in inner.ops
                               if o.kind == "dynamic-update-slice" and
                               len(o.operand_names) > 1]
                    if dus_upd:
                        # aliased accumulator: charge the window, not the buffer
                        alias = max((shape_bytes(t) for t in optypes
                                     if t.split("{")[0] ==
                                     op.result_type.split("{")[0]), default=0)
                        io_bytes = io_bytes - alias - rbytes + 2.0 * max(dus_upd)
                        io_bytes = max(io_bytes, 2.0 * max(dus_upd))
            elif kind in ("dot", "convolution", "custom-call", "copy",
                          "reduce", "transpose", "concatenate") or \
                    kind.split("-start")[0] in COLLECTIVES:
                io_bytes = sum(shape_bytes(t) for t in optypes) + rbytes
            if io_bytes is not None:
                out.hbm_bytes += io_bytes * mult
        seen_stack.pop()

    if entry:
        visit(entry, 1.0)
    return out
