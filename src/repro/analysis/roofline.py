"""Roofline model for TPU v5e (the target platform).

Hardware constants (per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI link bandwidth: ~50 GB/s/link (per direction); a v5e chip has 2 links
                      per torus axis — we charge collectives against ONE
                      axis's links (conservative single-axis model) and
                      report the per-device wire bytes from the HLO walk.

Terms per (arch × shape × mesh), all in seconds per step:
  compute    = HLO_FLOPs_per_device / peak
  memory     = HLO_bytes_per_device / hbm_bw
  collective = wire_bytes_per_device / ici_bw

The dominant term is the bottleneck; roofline fraction for the perf score is
  useful_model_flops_time / max(compute, memory, collective)
where useful_model_flops uses 6·N·D (dense train), 6·N_active·D (MoE), and
2·N·B per generated token for decode shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo import Analysis
from repro.config import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
ICI_LINKS = 2                # links per torus axis on v5e
HBM_PER_CHIP = 16 * 1024**3  # 16 GiB


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    num_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_per_dev: float
    hbm_bytes_per_dev: float
    wire_bytes_per_dev: float
    bytes_per_dev_peak: float      # from memory_analysis (argument+output+temp)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops across all devices): how much compiled
        compute is 'useful' — catches remat/dispatch overhead."""
        total = self.hlo_flops_per_dev * self.num_devices
        return self.model_flops / total if total > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chips' peak FLOP/s the step achieves on USEFUL
        model flops — the §Perf score."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return (self.model_flops / t) / (PEAK_FLOPS * self.num_devices)

    @property
    def fits_hbm(self) -> bool:
        return self.bytes_per_dev_peak <= HBM_PER_CHIP

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "devices": self.num_devices,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_per_dev": self.hlo_flops_per_dev,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bytes_per_dev": self.bytes_per_dev_peak,
            "fits_hbm": self.fits_hbm,
        }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful model FLOPs per step: 6·N·D train (N = active params), plus the
    attention term; decode: 2·N·B per emitted token + attention reads."""
    n_active = cfg.active_param_count()
    L, H, Dh = cfg.num_layers, cfg.num_heads, cfg.head_dim
    if shape.kind in ("train", "prefill"):
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0 if shape.kind == "train" else 2.0
        base = mult * n_active * tokens
        # causal attention: mult·B·L·H·Dh·S²/2 (fwd 2x ops qk+pv)
        attn = mult * shape.global_batch * L * H * Dh * shape.seq_len ** 2 / 2 \
            if cfg.attention != "nsa" else \
            mult * shape.global_batch * L * H * Dh * shape.seq_len * (
                cfg.nsa.n_selected * cfg.nsa.sel_block + cfg.nsa.window +
                shape.seq_len // cfg.nsa.cmp_stride)
        return base + attn
    # decode: one token per sequence
    base = 2.0 * n_active * shape.global_batch
    if cfg.attention == "nsa":
        ctx = (cfg.nsa.n_selected * cfg.nsa.sel_block + cfg.nsa.window +
               shape.seq_len // cfg.nsa.cmp_stride)
    else:
        ctx = shape.seq_len
    attn = 4.0 * shape.global_batch * L * H * Dh * ctx
    return base + attn


def build(arch: str, shape: ShapeConfig, mesh_name: str, num_devices: int,
          cfg: ModelConfig, hlo_analysis: Analysis, mem_bytes_per_dev: float,
          axis_group_hint: Optional[int] = None) -> Roofline:
    compute_s = hlo_analysis.flops / PEAK_FLOPS
    memory_s = hlo_analysis.hbm_bytes / HBM_BW
    collective_s = hlo_analysis.total_wire_bytes / (ICI_BW * ICI_LINKS)
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, num_devices=num_devices,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=model_flops(cfg, shape),
        hlo_flops_per_dev=hlo_analysis.flops,
        hbm_bytes_per_dev=hlo_analysis.hbm_bytes,
        wire_bytes_per_dev=hlo_analysis.total_wire_bytes,
        bytes_per_dev_peak=mem_bytes_per_dev)
