"""Deterministic synthetic LM corpus.

An order-2 Markov chain over token classes with per-class emission tables,
seeded and position-reproducible: ``batch(step)`` is a pure function of
(seed, step, shard), so any worker can regenerate any step's data after a
restart — the property the fault-tolerance tests rely on (no data-state in
checkpoints beyond the step counter).

The structure (strong local statistics + long-range class recurrence) gives
small trained models non-trivial next-token predictability, which is what
makes draft acceptance rates meaningful in the SSV end-to-end experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int = 512
    num_classes: int = 8
    class_concentration: float = 0.25   # lower -> peakier emissions
    transition_concentration: float = 0.5
    seed: int = 1234


class SyntheticCorpus:
    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        C, V = cfg.num_classes, cfg.vocab_size
        # class-pair transition matrix (order 2)
        self.trans = rng.dirichlet(np.full(C, cfg.transition_concentration),
                                   size=(C, C)).astype(np.float64)
        # per-class emissions over disjoint-ish vocab ranges (peaky)
        emis = rng.dirichlet(np.full(V, cfg.class_concentration), size=C)
        boost = np.zeros((C, V))
        span = V // C
        for c in range(C):
            boost[c, c * span:(c + 1) * span] = 3.0 / span
        self.emis = (emis + boost)
        self.emis /= self.emis.sum(-1, keepdims=True)

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        C = self.cfg.num_classes
        c1, c2 = rng.integers(C), rng.integers(C)
        out = np.empty(length, np.int64)
        for t in range(length):
            c_next = rng.choice(C, p=self.trans[c1, c2])
            out[t] = rng.choice(self.cfg.vocab_size, p=self.emis[c_next])
            c1, c2 = c2, c_next
        return out

    def batch(self, step: int, batch_size: int, seq_len: int,
              shard: int = 0, num_shards: int = 1) -> np.ndarray:
        """Deterministic (step, shard)-keyed batch of token sequences."""
        assert batch_size % num_shards == 0
        local = batch_size // num_shards
        out = np.empty((local, seq_len), np.int64)
        for i in range(local):
            rng = np.random.default_rng(
                (self.cfg.seed, step, shard * local + i))
            out[i] = self.sample(rng, seq_len)
        return out


def token_stream(corpus: SyntheticCorpus, batch_size: int, seq_len: int,
                 start_step: int = 0, shard: int = 0,
                 num_shards: int = 1) -> Iterator[Tuple[int, np.ndarray]]:
    step = start_step
    while True:
        yield step, corpus.batch(step, batch_size, seq_len, shard, num_shards)
        step += 1
