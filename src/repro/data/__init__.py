from repro.data.pipeline import PrefetchIterator, make_global_batch  # noqa: F401
from repro.data.synthetic import SyntheticConfig, SyntheticCorpus, token_stream  # noqa: F401
