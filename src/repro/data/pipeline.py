"""Sharded input pipeline: host-local generation + global-array assembly +
background prefetch.

In multi-host deployment each process generates only its shard
(``process_index``-keyed) and ``make_global_batch`` assembles a jax.Array
with the global (batch-sharded) sharding — the standard
``make_array_from_process_local_data`` pattern. On the single-process CI
runtime this degrades gracefully to a device_put with sharding.

Prefetching runs a depth-``prefetch`` background thread so host-side data
generation overlaps device compute — the first-line straggler mitigation for
input-bound steps.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import SyntheticCorpus


def make_global_batch(local: np.ndarray, sharding: Optional[jax.sharding.Sharding]):
    arr = jnp.asarray(local, jnp.int32)
    if sharding is None:
        return arr
    if jax.process_count() > 1:  # pragma: no cover - multi-host path
        return jax.make_array_from_process_local_data(sharding, local.astype(np.int32))
    return jax.device_put(arr, sharding)


class PrefetchIterator:
    """Wraps a (step, np.ndarray) iterator with a bounded background queue."""

    def __init__(self, it: Iterator, sharding=None, depth: int = 2):
        self.it = it
        self.sharding = sharding
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        try:
            for step, batch in self.it:
                if self._stop.is_set():
                    return
                self.q.put((step, make_global_batch(batch, self.sharding)))
        except Exception as e:  # surface in consumer
            self.q.put(e)
        self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
