"""Straggler detection & mitigation.

In SPMD JAX a slow host stalls every collective, so mitigation is (a) detect
— an EMA step-time watchdog flags steps beyond ``threshold``× the smoothed
time; (b) absorb — deep input prefetch (data/pipeline.py) and async
checkpointing keep host-side work off the critical path; (c) act — the
watchdog's callback can skip diagnostics, trigger re-meshing (elastic.py), or
page an operator. The policy object is deliberately dependency-free so it is
testable with injected clocks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ema: float
    ratio: float


class StragglerWatchdog:
    def __init__(self, threshold: float = 2.0, ema_alpha: float = 0.1,
                 warmup_steps: int = 5,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None):
        self.threshold = threshold
        self.alpha = ema_alpha
        self.warmup = warmup_steps
        self.on_straggler = on_straggler
        self.ema: Optional[float] = None
        self.events: List[StragglerEvent] = []
        self._seen = 0

    def observe(self, step: int, step_time: float) -> Optional[StragglerEvent]:
        self._seen += 1
        if self.ema is None:
            self.ema = step_time
            return None
        ratio = step_time / max(self.ema, 1e-9)
        ev = None
        if self._seen > self.warmup and ratio > self.threshold:
            ev = StragglerEvent(step=step, step_time=step_time, ema=self.ema,
                                ratio=ratio)
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
            # do not poison the EMA with the straggler sample
            return ev
        self.ema = self.alpha * step_time + (1 - self.alpha) * self.ema
        return ev
