"""Failure injection + restart policy for fault-tolerance testing.

``FailureInjector`` raises ``InjectedFailure`` at configured steps —
standing in for preemptions / host crashes. ``run_with_restarts`` wraps a
training driver: on failure it re-enters the driver, which resumes from the
latest checkpoint (the driver owns restore logic). This mirrors the
orchestrator-level restart loop of a real cluster scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, List, Optional, Set


class InjectedFailure(RuntimeError):
    pass


class FailureInjector:
    def __init__(self, fail_at_steps: Iterable[int] = (), max_failures: int = 10):
        self.fail_at: Set[int] = set(fail_at_steps)
        self.max_failures = max_failures
        self.failures: List[int] = []

    def maybe_fail(self, step: int):
        if step in self.fail_at and len(self.failures) < self.max_failures:
            self.fail_at.discard(step)
            self.failures.append(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class RestartReport:
    restarts: int
    completed: bool
    final_step: int


def run_with_restarts(driver: Callable[[], int], max_restarts: int = 5) -> RestartReport:
    """driver() runs/resumes training and returns the final step; raises on
    (injected) failure. Returns how many restarts were needed."""
    restarts = 0
    while True:
        try:
            final = driver()
            return RestartReport(restarts=restarts, completed=True,
                                 final_step=final)
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                return RestartReport(restarts=restarts, completed=False,
                                     final_step=-1)
