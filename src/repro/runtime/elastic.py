"""Elastic scaling: re-mesh planning and checkpoint-mediated resharding.

When the healthy device pool changes (node loss, capacity change), training
resumes on a new mesh: checkpoints are mesh-free (ckpt/checkpoint.py), so the
restart path is  plan_mesh(n_devices) -> build shardings for the new mesh ->
restore(..., shardings=new). ``plan_mesh`` picks the largest usable
(data, model) factorization preserving the model-parallel degree when
possible (TP degree is a property of the model's layout; DP degree flexes).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.config import MeshConfig


def plan_mesh(num_devices: int, prefer_model: int = 1,
              multi_pod: bool = False, pod_size: int = 0) -> MeshConfig:
    """Largest mesh <= num_devices. Keeps the model axis at ``prefer_model``
    when divisible, shrinking it only when unavoidable."""
    model = prefer_model
    while model > 1 and num_devices % model:
        model //= 2
    data = num_devices // model
    if multi_pod and pod_size and num_devices % pod_size == 0:
        pods = num_devices // pod_size
        data = pod_size // model
        return MeshConfig(shape=(pods, data, model), axes=("pod", "data", "model"))
    return MeshConfig(shape=(data, model), axes=("data", "model"))


def build_mesh(cfg: MeshConfig, devices=None) -> jax.sharding.Mesh:
    devices = devices if devices is not None else jax.devices()
    n = cfg.num_devices
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh(cfg.shape, cfg.axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(cfg.axes),
                         devices=devices[:n])
