"""Trainer: the fault-tolerant training driver.

Responsibilities:
  * jitted train step (loss + grad + clip + AdamW), with optional gradient
    accumulation over micro-batches and int8 error-feedback gradient
    compression for the cross-pod reduction;
  * deterministic (seed, step)-keyed data — restarts never replay or skip;
  * async checkpoint every N steps, resume-from-latest on construction;
  * straggler watchdog + failure-injection hook wired into the step loop.

The same ``make_train_step`` is what the multi-pod dry-run lowers with
ShapeDtypeStructs — trainer and dry-run share one definition of "a step".
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import AsyncCheckpointer, latest_step, restore
from repro.config import ModelConfig, TrainConfig
from repro.data.synthetic import SyntheticConfig, SyntheticCorpus
from repro.models import model
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, compress
from repro.runtime.fault import FailureInjector
from repro.runtime.straggler import StragglerWatchdog


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    residual: Any                 # error-feedback residual (compression) or None
    step: int = 0


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    donate: bool = True, jit: bool = True,
                    constrain=None) -> Callable:
    """Returns step(params, opt, residual, tokens) ->
    (params, opt, residual, metrics). ``jit=False`` returns the raw function
    (the dry-run jits it itself with explicit in/out shardings);
    ``constrain`` pins the residual-stream sharding (launch/sharding.py)."""
    use_comp = tcfg.grad_compression == "int8_ef"

    def step_fn(params, opt, residual, tokens):
        def loss_of(p, batch):
            return model.loss_fn(p, cfg, batch, remat=tcfg.remat,
                                 constrain=constrain)

        if tcfg.micro_batches > 1:
            mb = tokens.reshape((tcfg.micro_batches,
                                 tokens.shape[0] // tcfg.micro_batches) +
                                tokens.shape[1:])

            def acc_body(carry, batch):
                loss, g = jax.value_and_grad(loss_of)(params, batch)
                a_loss, a_g = carry
                return (a_loss + loss, jax.tree.map(jnp.add, a_g, g)), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (jnp.float32(0.0), zero_g), mb)
            loss = loss / tcfg.micro_batches
            grads = jax.tree.map(lambda g: g / tcfg.micro_batches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, tokens)

        if use_comp:
            quant, residual = compress.compress_pytree(grads, residual,
                                                       opt.count)
            grads = compress.decompress_pytree(quant)

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        params, opt = adamw_update(grads, opt, params, tcfg)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return params, opt, residual, metrics

    if not jit:
        return step_fn
    return jax.jit(step_fn, donate_argnums=(0, 1, 2) if donate else ())


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 data_cfg: Optional[SyntheticConfig] = None,
                 batch_size: int = 8, seq_len: int = 128,
                 injector: Optional[FailureInjector] = None,
                 resume: bool = True):
        self.cfg, self.tcfg = cfg, tcfg
        self.batch_size, self.seq_len = batch_size, seq_len
        self.corpus = SyntheticCorpus(data_cfg or SyntheticConfig(
            vocab_size=cfg.vocab_size, seed=tcfg.seed))
        self.injector = injector
        self.watchdog = StragglerWatchdog()
        self.ckpt = AsyncCheckpointer(tcfg.checkpoint_dir)
        self.metrics_log: List[Dict[str, float]] = []

        params = model.init(jax.random.PRNGKey(tcfg.seed), cfg)
        opt = adamw_init(params)
        residual = (compress.init_residual(params)
                    if tcfg.grad_compression == "int8_ef" else jnp.zeros(()))
        self.state = TrainState(params=params, opt=opt, residual=residual, step=0)
        if resume and latest_step(tcfg.checkpoint_dir) is not None:
            tmpl = {"params": self.state.params, "opt": self.state.opt,
                    "residual": self.state.residual}
            step, tree = restore(tcfg.checkpoint_dir, tmpl)
            self.state = TrainState(params=tree["params"], opt=tree["opt"],
                                    residual=tree["residual"], step=step)
        self._step_fn = make_train_step(cfg, tcfg)

    def save(self):
        self.ckpt.save(self.state.step,
                       {"params": self.state.params, "opt": self.state.opt,
                        "residual": self.state.residual},
                       metadata={"model": self.cfg.name})

    def run(self, steps: Optional[int] = None) -> int:
        end = self.tcfg.steps if steps is None else self.state.step + steps
        while self.state.step < end:
            step = self.state.step
            if self.injector is not None:
                self.injector.maybe_fail(step)
            batch = jnp.asarray(self.corpus.batch(step, self.batch_size,
                                                  self.seq_len), jnp.int32)
            t0 = time.perf_counter()
            params, opt, residual, metrics = self._step_fn(
                self.state.params, self.state.opt, self.state.residual, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self.watchdog.observe(step, dt)
            self.state = TrainState(params=params, opt=opt, residual=residual,
                                    step=step + 1)
            metrics["step"] = step
            metrics["time_s"] = dt
            self.metrics_log.append(metrics)
            if (step + 1) % self.tcfg.checkpoint_every == 0 or step + 1 == end:
                self.save()
        self.ckpt.wait()
        return self.state.step
