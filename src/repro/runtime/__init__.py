from repro.runtime import elastic, fault, straggler, trainer  # noqa: F401
