"""Draft trees: construction geometry, BFS/DFS flattening, tree attention
masks, and acceptance-path bookkeeping (paper §4.1).

Trees are *rooted*: node 0 is the **pending token** — the last generated
token whose K/V has not yet entered the cache (the previous step's bonus
token, or the last prompt token right after prefill). Verifying the tree
computes the pending token's K/V alongside the draft nodes, so committing the
accepted path (which always starts at node 0) keeps the cache exact. A draft
tree of depth D and branching width k then has 1 + k + k^2 + ... + k^D nodes.

Topology is *static* per strategy: (D, k, traversal, budget) fix parents,
depths, and masks; only token ids are data — every verification step is a
fixed-shape jitted computation.

Traversal orders (paper: thread-block grouping prefers different adjacency):
  * BFS — siblings adjacent (same depth grouped);
  * DFS — parent/child chains adjacent.
Both orders list parents before children (topological), which the recurrent
state-replay verifier also requires. Node 0 stays first in both orders.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TreeTopology:
    """Static topology of a flattened draft tree.

    parents[i]  — index of node i's parent in flattened order (-1 = root/committed)
    depths[i]   — 1-based depth (position offset from the committed prefix)
    mask[i, j]  — node i attends node j (ancestor-or-self relation)
    paths       — (n_leaves, D) node indices of each root-to-leaf path, -1 padded
    """

    parents: np.ndarray
    depths: np.ndarray
    mask: np.ndarray
    paths: np.ndarray
    order: str

    @property
    def num_nodes(self) -> int:
        return len(self.parents)


def _build_children(depth: int, width: int, budget: int) -> Tuple[List[int], List[int]]:
    """BFS-enumerate the rooted (D, k) tree (level order), draft nodes
    truncated to ``budget``. Returns (parents_bfs, depths_bfs); node 0 is the
    pending root at depth 0."""
    parents = [-1]
    depths = [0]
    level = [0]  # previous level's node ids
    nid = 1
    for d in range(1, depth + 1):
        nxt = []
        for p in level:
            for _ in range(width):
                if budget and nid > budget:
                    return parents, depths
                parents.append(p)
                depths.append(d)
                nxt.append(nid)
                nid += 1
        level = nxt
        if not level:
            break
    return parents, depths


@functools.lru_cache(maxsize=256)
def build_topology(depth: int, width: int, order: str = "bfs",
                   budget: int = 0) -> TreeTopology:
    parents_bfs, depths_bfs = _build_children(depth, width, budget)
    n = len(parents_bfs)
    if order == "bfs":
        perm = list(range(n))
    elif order == "dfs":
        children: List[List[int]] = [[] for _ in range(n + 1)]
        for i, p in enumerate(parents_bfs):
            children[p + 1].append(i)
        perm = []

        def visit(b):
            for c in children[b + 1]:
                perm.append(c)
                visit(c)

        visit(-1)  # root (bfs id 0) is the only child of -1, stays first
    else:
        raise ValueError(f"unknown traversal order {order!r}")
    inv = {b: i for i, b in enumerate(perm)}
    parents = np.array([inv[parents_bfs[b]] if parents_bfs[b] >= 0 else -1
                        for b in perm], np.int32)
    depths = np.array([depths_bfs[b] for b in perm], np.int32)
    # topological check: parents precede children in flattened order
    assert all(parents[i] < i for i in range(n)), "traversal must be topological"

    mask = np.zeros((n, n), bool)
    for i in range(n):
        j = i
        while j >= 0:
            mask[i, j] = True
            j = parents[j]

    # leaves: nodes with no children
    has_child = np.zeros(n, bool)
    for i in range(n):
        if parents[i] >= 0:
            has_child[parents[i]] = True
    leaves = np.where(~has_child)[0]
    maxd = int(depths.max()) if n else 0
    paths = np.full((len(leaves), maxd + 1), -1, np.int32)  # root included
    for li, leaf in enumerate(leaves):
        chain = []
        j = leaf
        while j >= 0:
            chain.append(j)
            j = parents[j]
        chain.reverse()
        paths[li, : len(chain)] = chain
    return TreeTopology(parents=parents, depths=depths, mask=mask, paths=paths,
                        order=order)


def children_matrix(topo: TreeTopology) -> np.ndarray:
    """(T, k_max) int32: children of each node in sibling order, -1 padded.

    Static per topology — the device-side accept walks scan over it. k_max is
    the max child count over nodes (>= 1 so the array is never 0-width).
    """
    n = topo.num_nodes
    ch: List[List[int]] = [[] for _ in range(n)]
    for i in range(n):
        p = int(topo.parents[i])
        if p >= 0:
            ch[p].append(i)
    kmax = max([len(c) for c in ch] + [1])
    mat = np.full((n, kmax), -1, np.int32)
    for i, c in enumerate(ch):
        mat[i, : len(c)] = c
    return mat


def positions_for(topo: TreeTopology, prefix_len) -> np.ndarray:
    """Absolute positions of flattened nodes: the pending root (depth 0) sits
    at position prefix_len; depth-d draft nodes at prefix_len + d."""
    return prefix_len + topo.depths


def chain_topology(gamma: int) -> TreeTopology:
    """Degenerate tree: pending root + a single chain of gamma draft tokens
    (classic non-tree speculation)."""
    return build_topology(gamma, 1, "bfs", 0)
