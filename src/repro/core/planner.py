"""Profile-guided prompt-adaptive orchestration (paper §6).

Strategy space: θ_d = (tree depth D, width k, traversal T),
θ_s = (coarsening factor C, mode M, refresh/reuse schedule S), constrained by
a precision class P ∈ {Strict, Reuse-only, Approx-only, Approx+Reuse}:

    Strict       — exact coarsening, all-refresh schedule
    Reuse-only   — exact coarsening, refresh/reuse schedule
    Approx-only  — approximate coarsening, all-refresh
    Approx+Reuse — approximate coarsening + refresh/reuse schedule

The offline profiler runs the full engine on a calibration prompt set per
(context regime r, P), measures E[A] (accepted tokens/step) and E[T] (step
latency), and stores a ranked candidate list per bucket — a lookup table
analogous to the paper's 192-entry profile (4 buckets × 4 classes × 12
candidates).

Runtime guard (Algorithm 1 + §6.3): EMA-smoothed accepted counts with
α = 0.40; after an m = 8 step warmup, if the smoothed value stays below
ρ = 0.85 × the profiled expectation for h = 5 consecutive steps, switch to
the next-ranked strategy; at most 2 transitions per request, falling back to
the best strategy explored so far if the mismatch persists.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import SSVConfig

PRECISION_CLASSES = ("Strict", "Reuse-only", "Approx-only", "Approx+Reuse")
DEFAULT_BUCKETS = ((0, 4096), (4096, 8192), (8192, 12288), (12288, 16384))

# Paper §6.3 constants
ALPHA = 0.40      # EMA coefficient
RHO = 0.85        # acceptance-drop ratio
WARMUP_M = 8      # minimum observation count
HYSTERESIS_H = 5  # consecutive below-threshold steps before switching
MAX_TRANSITIONS = 2


def class_constraints(precision_class: str) -> Tuple[str, bool]:
    """-> (group_mode, reuse_allowed)."""
    return {
        "Strict": ("exact", False),
        "Reuse-only": ("exact", True),
        "Approx-only": ("approx", False),
        "Approx+Reuse": ("approx", True),
    }[precision_class]


def default_schedule(num_layers: int) -> Tuple[int, ...]:
    """Alternating refresh/reuse (paper §7.2 evaluation schedule): odd layers
    reuse. Layer 0 is always a refresh."""
    return tuple(i for i in range(1, num_layers, 2))


def candidate_strategies(precision_class: str, num_layers: int,
                         schedule: Optional[Tuple[int, ...]] = None) -> List[SSVConfig]:
    """Enumerate the valid strategy tuples for one precision class — the
    profiler ranks these. 12 candidates per class (paper's table width)."""
    mode, reuse = class_constraints(precision_class)
    sched = (schedule if schedule is not None else default_schedule(num_layers)) if reuse else ()
    shapes = [  # (D, k, budget)
        (6, 4, 0), (6, 10, 128), (4, 2, 0), (4, 4, 0), (8, 2, 0), (3, 8, 0),
    ]
    cands = []
    for D, k, budget in shapes:
        for trav in ("bfs", "dfs"):
            C = 4 if mode == "approx" else 2
            cands.append(SSVConfig(
                tree_depth=D, tree_width=k, traversal=trav, tree_budget=budget,
                group_size=C, group_mode=mode, refresh_schedule=sched,
                precision_class=precision_class))
    return cands


def bucket_of(context_len: int, buckets=DEFAULT_BUCKETS) -> int:
    for i, (lo, hi) in enumerate(buckets):
        if lo <= context_len < hi:
            return i
    return len(buckets) - 1


@dataclasses.dataclass
class ProfileEntry:
    strategy: SSVConfig
    expected_accept: float    # E[A]
    expected_latency: float   # E[T]

    @property
    def throughput(self) -> float:
        return (self.expected_accept + 1.0) / max(self.expected_latency, 1e-9)


@dataclasses.dataclass
class Profile:
    """Lookup table: (bucket, precision class) -> ranked ProfileEntry list."""
    table: Dict[Tuple[int, str], List[ProfileEntry]]
    buckets: Tuple[Tuple[int, int], ...] = DEFAULT_BUCKETS

    def lookup(self, context_len: int, precision_class: str) -> List[ProfileEntry]:
        return self.table[(bucket_of(context_len, self.buckets), precision_class)]

    def to_json(self) -> str:
        enc = {}
        for (b, p), entries in self.table.items():
            enc[f"{b}|{p}"] = [
                {"strategy": dataclasses.asdict(e.strategy),
                 "expected_accept": e.expected_accept,
                 "expected_latency": e.expected_latency} for e in entries]
        return json.dumps({"buckets": self.buckets, "table": enc}, indent=2)

    @classmethod
    def from_json(cls, s: str) -> "Profile":
        raw = json.loads(s)
        table = {}
        for key, entries in raw["table"].items():
            b, p = key.split("|")
            table[(int(b), p)] = [
                ProfileEntry(strategy=SSVConfig(**{
                    **e["strategy"],
                    "refresh_schedule": tuple(e["strategy"]["refresh_schedule"])}),
                    expected_accept=e["expected_accept"],
                    expected_latency=e["expected_latency"]) for e in entries]
        return cls(table=table,
                   buckets=tuple(tuple(b) for b in raw["buckets"]))


def build_profile(run_fn, precision_classes=PRECISION_CLASSES,
                  buckets=DEFAULT_BUCKETS, num_layers: int = 8,
                  max_candidates: int = 12, schedule=None) -> Profile:
    """Offline profiling. ``run_fn(strategy, bucket_idx) -> (E[A], E[T])``
    runs the end-to-end engine on the calibration set for that regime."""
    table: Dict[Tuple[int, str], List[ProfileEntry]] = {}
    for b in range(len(buckets)):
        for pc in precision_classes:
            entries = []
            for strat in candidate_strategies(pc, num_layers, schedule)[:max_candidates]:
                ea, et = run_fn(strat, b)
                entries.append(ProfileEntry(strat, float(ea), float(et)))
            entries.sort(key=lambda e: -e.throughput)
            table[(b, pc)] = entries
    return Profile(table=table, buckets=buckets)


class RuntimePlanner:
    """Algorithm 1: preselect from the profile, refine during early steps."""

    def __init__(self, profile: Profile, precision_class: str = "Strict",
                 alpha: float = ALPHA, rho: float = RHO, warmup_m: int = WARMUP_M,
                 hysteresis_h: int = HYSTERESIS_H,
                 max_transitions: int = MAX_TRANSITIONS,
                 early_window: int = 64):
        self.profile = profile
        self.pc = precision_class
        self.alpha, self.rho = alpha, rho
        self.warmup_m, self.h = warmup_m, hysteresis_h
        self.max_transitions = max_transitions
        self.early_window = early_window
        self._reset()

    def _reset(self):
        self.rank = 0
        self.entries: List[ProfileEntry] = []
        self.ema: Optional[float] = None
        self.below = 0
        self.steps = 0
        self.transitions = 0
        self.explored: List[Tuple[int, float, float]] = []  # (rank, mean A, mean T)
        self._acc_hist: List[float] = []
        self._lat_hist: List[float] = []
        self.refinement_events = 0

    # ---------------------------------------------------------------- API
    def begin_request(self, context_len: int):
        self._reset()
        self.entries = self.profile.lookup(context_len, self.pc)

    def current(self) -> SSVConfig:
        return self.entries[min(self.rank, len(self.entries) - 1)].strategy

    def observe(self, accepted: int, latency_s: float):
        self.steps += 1
        self._acc_hist.append(accepted)
        self._lat_hist.append(latency_s)
        self.ema = accepted if self.ema is None else \
            self.alpha * accepted + (1 - self.alpha) * self.ema
        if self.steps > self.early_window:
            return
        expected = self.entries[min(self.rank, len(self.entries) - 1)].expected_accept
        if self.steps >= self.warmup_m and self.ema < self.rho * expected:
            self.below += 1
        else:
            self.below = 0
        if self.below >= self.h:
            self._refine()

    # ---------------------------------------------------------------- guard
    def _refine(self):
        self.explored.append((self.rank, float(np.mean(self._acc_hist[-self.h:])),
                              float(np.mean(self._lat_hist[-self.h:]))))
        if self.transitions < self.max_transitions and self.rank + 1 < len(self.entries):
            self.rank += 1
            self.transitions += 1
            self.refinement_events += 1
            self.below = 0
            self.ema = None
        else:
            # mismatch persists: pick the best configuration explored so far
            if self.explored:
                best = max(self.explored,
                           key=lambda e: (e[1] + 1.0) / max(e[2], 1e-9))
                self.rank = best[0]
            self.below = 0


class BatchPlanner:
    """Bucket-local batched planning: profile-guided execution groups for a
    mixed-length continuous batch.

    Where ``RuntimePlanner`` drives ONE strategy per request stream, the
    BatchPlanner partitions the live batch slots by context-regime bucket and
    assigns each group the profile's top-ranked strategy for its (bucket,
    precision class). Every bucket carries its own runtime guard — a full
    ``RuntimePlanner`` seeded at that bucket's profile entries — so the EMA /
    hysteresis refinement machinery (Algorithm 1) runs per execution group:
    a long-context group refining to its next-ranked strategy never perturbs
    the short-context group's plan.

    The engine (``BatchedSSVEngine.serve_continuous`` with bucketed mode)
    asks ``plan`` for the execution groups each fused-step round, launches
    one fused step per group under ``strategy_for(bucket)``, and feeds the
    group's mean acceptance back through ``observe(bucket, ...)``.
    """

    is_batch_planner = True

    def __init__(self, profile: Profile, precision_class: str = "Strict",
                 alpha: float = ALPHA, rho: float = RHO,
                 warmup_m: int = WARMUP_M, hysteresis_h: int = HYSTERESIS_H,
                 max_transitions: int = MAX_TRANSITIONS,
                 early_window: int = 64):
        missing = [b for b in range(len(profile.buckets))
                   if not profile.table.get((b, precision_class))]
        if missing:
            have = sorted({pc for (_, pc) in profile.table})
            raise ValueError(
                f"profile has no ranked strategies for precision class "
                f"{precision_class!r} in bucket(s) {missing} — a request "
                "landing there could not be planned; this profile covers "
                f"{have}")
        self.profile = profile
        self.pc = precision_class
        self._guard_kwargs = dict(alpha=alpha, rho=rho, warmup_m=warmup_m,
                                  hysteresis_h=hysteresis_h,
                                  max_transitions=max_transitions,
                                  early_window=early_window)
        self.max_transitions = max_transitions
        self.guards: Dict[int, RuntimePlanner] = {}

    # ---------------------------------------------------------------- buckets
    def bucket_of(self, context_len: int) -> int:
        return bucket_of(context_len, self.profile.buckets)

    def begin_serve(self):
        """Reset every bucket guard — call once per serving run."""
        self.guards = {}

    def _guard(self, bucket: int) -> RuntimePlanner:
        g = self.guards.get(bucket)
        if g is None:
            g = RuntimePlanner(self.profile, self.pc, **self._guard_kwargs)
            # seed the guard at the bucket's representative context length
            g.begin_request(context_len=self.profile.buckets[bucket][0])
            self.guards[bucket] = g
        return g

    # ---------------------------------------------------------------- plan
    def strategy_for(self, bucket: int) -> SSVConfig:
        """Current (guard-refined) strategy of one bucket's execution group."""
        return self._guard(bucket).current()

    def plan(self, slot_buckets: Dict[int, int]) -> List[Tuple[int, List[int]]]:
        """Partition live slots into bucket-homogeneous execution groups.

        ``slot_buckets``: slot index -> context bucket for every slot to
        advance this round. Returns ``[(bucket, [slots...]), ...]`` sorted by
        bucket then slot — a deterministic launch order, so serving replays
        are reproducible."""
        groups: Dict[int, List[int]] = {}
        for slot, b in slot_buckets.items():
            groups.setdefault(int(b), []).append(int(slot))
        return [(b, sorted(slots)) for b, slots in sorted(groups.items())]

    def observe(self, bucket: int, accepted: float, latency_s: float):
        """Feed one group-step's mean acceptance into that bucket's guard."""
        self._guard(bucket).observe(accepted=accepted, latency_s=latency_s)

    # ---------------------------------------------------------------- warmup
    def reachable_strategies(self) -> List[SSVConfig]:
        """Every strategy a serving run can launch: per bucket, the ranks the
        guard can walk to (top rank + at most ``max_transitions`` refinement
        hops). This is the AOT warmup set — compiling it up front means a
        mid-serve strategy switch never stalls the batch on a retrace."""
        out: List[SSVConfig] = []
        for b in range(len(self.profile.buckets)):
            entries = self.profile.table.get((b, self.pc), [])
            for e in entries[: self.max_transitions + 1]:
                if e.strategy not in out:
                    out.append(e.strategy)
        return out

    @property
    def refinement_events(self) -> int:
        return sum(g.refinement_events for g in self.guards.values())
