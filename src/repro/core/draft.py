"""Draft model + tree expansion.

The draft is a small dense transformer sharing the target's vocabulary (the
classic two-model speculative setup). An EAGLE-style feature-fusion hook is
available: when ``feature_fusion`` is on, the draft's input embedding at the
pending root is augmented with the target's last hidden state (projected),
which is how EAGLE-3 conditions the draft on target features.

Tree expansion runs level by level: level-(d+1) candidate tokens are the
top-k of the draft's logits at the depth-d nodes. Each level re-verifies the
partial tree through the draft's own ``verify_step`` (tree-masked), so the
draft KV used for deeper levels is exact. The final full-tree pass also
yields the draft-side K/V updates used to commit accepted tokens into the
draft cache, and the per-node draft distributions ``node_q`` consumed by
stochastic acceptance.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.tree import TreeTopology, positions_for
from repro.models import layers, model


def draft_config(target_cfg: ModelConfig, num_layers: int = 2, d_model: int = 0,
                 name: str = "") -> ModelConfig:
    d = d_model or max(64, target_cfg.d_model // 4)
    heads = max(2, target_cfg.num_heads // 4)
    while d % heads:
        heads -= 1
    return dataclasses.replace(
        target_cfg,
        name=name or f"{target_cfg.name}-draft",
        num_layers=num_layers,
        d_model=d,
        num_heads=heads,
        num_kv_heads=heads,
        head_dim=0,
        d_ff=2 * d,
        attention="dense",
        block_pattern=("attn",),
        moe=None,
        recurrent=None,
        modality="text",
        frontend_dim=0,
    )


def sibling_ranks(topo: TreeTopology) -> np.ndarray:
    """rank[i] = index of node i among its siblings (drives top-k assignment)."""
    T = topo.num_nodes
    rank = np.zeros(T, np.int64)
    seen: dict = {}
    for i in range(1, T):
        p = int(topo.parents[i])
        rank[i] = seen.get(p, 0)
        seen[p] = rank[i] + 1
    return rank


def expand_tree(verify_fn, draft_cfg: ModelConfig, draft_caches, topo: TreeTopology,
                pending_token, temperature: float = 0.0):
    """Fill the tree's token ids by expanding with the draft model.

    verify_fn(caches, tokens, positions, tmask, parents) -> (logits, updates)
    — typically a jitted closure over the draft params/config.
    pending_token: (B,) int32 — the tree root's token.
    Returns (tokens (B, T), node_q (B, T, V) draft distributions, updates)
    where ``updates`` are the draft verify-step cache updates of the final
    full-tree pass (for committing).
    """
    B = pending_token.shape[0]
    T = topo.num_nodes
    prefix = draft_caches["length"]
    positions = jnp.asarray(positions_for(topo, 0))[None] + prefix
    positions = jnp.broadcast_to(positions, (B, T)).astype(jnp.int32)
    tmask = jnp.broadcast_to(jnp.asarray(topo.mask)[None], (B, T, T))
    parents = jnp.asarray(topo.parents)

    tokens = jnp.zeros((B, T), jnp.int32).at[:, 0].set(pending_token)
    depths = topo.depths
    maxd = int(depths.max()) if T > 1 else 0
    rank = sibling_ranks(topo)
    node_q = None
    updates = None

    for d in range(maxd + 1):
        logits, updates = verify_fn(draft_caches, tokens, positions, tmask, parents)
        scaled = logits.astype(jnp.float32)
        if temperature > 0:
            scaled = scaled / temperature
        node_q = jax.nn.softmax(scaled, axis=-1)
        if d == maxd:
            break
        # assign depth-(d+1) tokens: child i gets the rank[i]-th top token of
        # its parent's draft logits
        level = np.where(depths == d + 1)[0]
        kmax = int(rank[level].max()) + 1 if len(level) else 1
        _, topk_idx = jax.lax.top_k(logits, kmax)                    # (B, T, kmax)
        par = jnp.asarray(topo.parents[level])
        rk = jnp.asarray(rank[level])
        picked = topk_idx[:, par, rk]                                # (B, |level|)
        tokens = tokens.at[:, jnp.asarray(level)].set(picked)
    return tokens, node_q, updates
