"""SSVEngine — the end-to-end draft → sparse-verify → accept serving loop
(paper Fig. 3), with pluggable verification strategy (θ_d, θ_s), precision
class P, and planner-driven prompt adaptation.

Per generation step:
  1. the planner supplies the active strategy (tree shape, traversal,
     grouping, refresh/reuse schedule);
  2. the draft model expands a rooted token tree under the pending token;
  3. the target verifies all nodes in one tree-masked pass — NSA layers run
     the refresh/reuse schedule and exact/approx grouped selection;
  4. accept/reject picks the longest valid path + a bonus token **on
     device**, fused into the same jitted step as verification and the
     target-cache commit — the (T, vocab) verification logits never leave
     the accelerator; only the accepted path tokens, n_accepted, and the
     bonus token (a few ints) cross to the host;
  5. both models commit the accepted path's K/V (or recurrent states) with
     **donated** cache buffers — commits update the max_context-sized caches
     in place instead of double-allocating them;
  6. step statistics (A_t, T_t) feed the planner's runtime guard.

The committed sequence length is tracked host-side (updated from the
n_accepted scalar the loop fetches anyway), so the generate loop never
blocks on a device sync of ``caches["length"]``.

All device computations are jitted and cached per (config, strategy, tree
topology) — fixed shapes, no recompilation inside a generation.
`BatchedSSVEngine` vectorizes the whole step (draft expansion, tree
verification, accept, donated commits) over a request batch with
per-request lengths and completion masks.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig, SSVConfig
from repro.core import accept as accept_lib
from repro.core import draft as draft_lib
from repro.core import kvstore
from repro.core import overlap as overlap_lib
from repro.core import schedule as schedule_lib
from repro.core.tree import build_topology, children_matrix
from repro.models import model


# ------------------------------------------------------------ jit caches
# ModelConfig / SSVConfig are frozen dataclasses — they hash and compare by
# value, so two equal configs share one cache entry and planner strategy
# switches never silently recompile inside a generation (each distinct
# (config, strategy, topology shape) is traced at most once; see
# tests/test_engine_batched.py::test_jit_cache_keys_by_value).
@functools.lru_cache(maxsize=64)
def jit_verify(cfg: ModelConfig, ssv: Optional[SSVConfig]):
    def f(params, caches, tokens, positions, tmask, parents):
        return model.verify_step(params, cfg, caches, tokens, positions, tmask,
                                 parents, ssv)
    return jax.jit(f)


@functools.lru_cache(maxsize=64)
def jit_commit(cfg: ModelConfig):
    # caches donated: the commit's output KV buffers alias the inputs —
    # no second max_context-sized allocation per step.
    def f(params, caches, updates, accepted, n_accepted):
        return model.commit(params, cfg, caches, updates, accepted, n_accepted)
    return jax.jit(f, donate_argnums=(1,))


@functools.lru_cache(maxsize=64)
def jit_prefill(cfg: ModelConfig, max_len: int):
    # prefill builds the caches from scratch — there is no input cache buffer
    # to donate; the prompt token array is tiny, so nothing else is worth it.
    def f(params, tokens):
        return model.prefill(params, cfg, tokens, max_len)
    return jax.jit(f)


@functools.lru_cache(maxsize=64)
def jit_verify_accept(cfg: ModelConfig, ssv: SSVConfig, greedy: bool,
                      temperature: float):
    """Fused verify → tree-accept → commit step for the target model.

    The tree topology is a pure function of ``ssv`` and is closed over as
    static arrays. Only the accepted tokens / path / counts are returned to
    the caller alongside the (donated, updated-in-place) caches — the
    (T, vocab) logits tensor stays on device.

    Greedy signature:     f(params, caches, tokens)
    Stochastic signature: f(params, caches, tokens, node_q, accept_u, bonus_u)
    Returns (new_caches, path (pad,), tokens (pad+1,), bonus, n_accepted_path)
    where n_accepted_path counts accepted DRAFT nodes (excl. root/bonus) and
    path/n include the pending root as commit expects.
    """
    topo = build_topology(ssv.tree_depth, ssv.tree_width, ssv.traversal,
                          ssv.tree_budget)
    depths = jnp.asarray(topo.depths)
    tmask = jnp.asarray(topo.mask)
    parents = jnp.asarray(topo.parents)
    child_mat = jnp.asarray(children_matrix(topo))
    maxd = int(topo.depths.max()) if topo.num_nodes else 0

    def core(params, caches, tokens, accept_fn):
        B, T = tokens.shape
        positions = (depths[None] + caches["length"]).astype(jnp.int32)
        positions = jnp.broadcast_to(positions, (B, T))
        logits, updates = model.verify_step(
            params, cfg, caches, tokens, positions,
            jnp.broadcast_to(tmask[None], (B, T, T)), parents, ssv)
        path, out_tokens, bonus, n_acc = accept_fn(tokens[0], logits[0])
        new_caches = model.commit(params, cfg, caches, updates,
                                  path[None], (n_acc + 1)[None])
        return new_caches, path, out_tokens, bonus, n_acc

    if greedy:
        def f(params, caches, tokens):
            return core(params, caches, tokens,
                        lambda tk, lg: accept_lib.greedy_tree_accept_device(
                            child_mat, maxd, tk, lg))
    else:
        def f(params, caches, tokens, node_q, accept_u, bonus_u):
            return core(params, caches, tokens,
                        lambda tk, lg: accept_lib.stochastic_tree_accept_device(
                            child_mat, maxd, tk, lg, node_q[0], accept_u,
                            bonus_u, temperature))
    return jax.jit(f, donate_argnums=(1,))


def _resolve_store(serve_cfg: ServeConfig, target_cfg: ModelConfig) -> kvstore.KVStoreConfig:
    """Pin the page size against the TARGET model once: target and draft
    share one page table, so both pools must tile tokens identically (the
    dense-attention draft has no sel_block constraint of its own)."""
    store = kvstore.KVStoreConfig(serve_cfg.kv_backend, serve_cfg.kv_page_size,
                                  serve_cfg.kv_num_pages)
    if store.is_paged:
        store = dataclasses.replace(
            store, page_size=store.resolved_page_size(target_cfg))
    return store


def max_draft_gamma(serve_cfg: ServeConfig, planner) -> int:
    """Largest draft-tree size any step can run: the base strategy plus —
    when a planner is attached — every strategy in its profile (a mid-run
    refinement can switch to any of them)."""
    g = serve_cfg.ssv.num_draft_tokens()
    profile = getattr(planner, "profile", None)
    if profile is not None:
        for entries in profile.table.values():
            for e in entries:
                g = max(g, e.strategy.num_draft_tokens())
    return g


def step_headroom(serve_cfg: ServeConfig, planner) -> int:
    """Tokens a request's cache region must leave free beyond its budget: a
    commit writes the whole padded accepted path before the budget check
    truncates it. Both engines size admission (dense max_context bound AND
    paged page reservation) with this one bound."""
    return 2 * (max_draft_gamma(serve_cfg, planner) + 2)


def request_pages(serve_cfg: ServeConfig, planner, page_size: int,
                  max_pages: int, prompt_len: int,
                  max_new_tokens: int = 0) -> int:
    """Pages a request reserves for its whole life: committed prompt + token
    budget + speculative-step overshoot (a commit writes the padded path
    before the budget check truncates it), capped at the logical row
    capacity. ONE function sizes both the single-stream and the batched
    engines' reservations — page needs never grow mid-flight, so a full
    pool can only delay admission, never deadlock or preempt a live row."""
    budget = max_new_tokens or serve_cfg.max_new_tokens
    toks = min(prompt_len - 1 + budget + step_headroom(serve_cfg, planner),
               serve_cfg.max_context)
    return min(kvstore.pages_needed(toks, page_size), max_pages)


def kernel_cache_stats() -> Dict[str, int]:
    """Process-wide kernel-layer cache counters, reported in engine metrics
    next to ``kv_cache_bytes``: the fused-verify kernel build cache
    (``kernels/nsa_verify/ops._cached_call``) and the (T, C) query-group
    layout cache (``overlap.group_queries``). Both caches are shared by
    every engine in the process."""
    from repro.kernels.nsa_verify import ops as nsa_ops
    vc = nsa_ops.verify_call_cache_info()
    gq = overlap_lib.group_queries.cache_info()
    return {"verify_call_hits": vc.hits, "verify_call_misses": vc.misses,
            "verify_call_cached": vc.currsize,
            "group_layout_hits": gq.hits, "group_layout_misses": gq.misses,
            "group_layout_cached": gq.currsize}


def step_host_transfer_elems(ssv: SSVConfig) -> int:
    """Elements the fused step hands to the host per iteration: the padded
    accepted-token vector plus the (bonus, n_accepted) scalars. Compare with
    the T × vocab logits tensor the host-side accept used to pull."""
    topo = build_topology(ssv.tree_depth, ssv.tree_width, ssv.traversal,
                          ssv.tree_budget)
    maxd = int(topo.depths.max()) if topo.num_nodes else 0
    return (maxd + 1) + 2


@dataclasses.dataclass
class StepStats:
    accepted: int          # draft tokens accepted (A_t excludes the bonus)
    emitted: int           # new tokens emitted this step (accepted + 1 bonus)
    latency_s: float       # T_t
    gamma: int             # draft tokens verified
    strategy: SSVConfig
    host_elems: int = 0    # device->host elements fetched this step
    phases: Optional[Dict[str, float]] = None  # draft/verify_accept/commit (instrumented)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray
    steps: List[StepStats]

    @property
    def accepted_token_throughput(self) -> float:
        tot_t = sum(s.latency_s for s in self.steps)
        tot_e = sum(s.emitted for s in self.steps)
        return tot_e / tot_t if tot_t > 0 else 0.0

    @property
    def mean_accepted(self) -> float:
        return float(np.mean([s.accepted for s in self.steps])) if self.steps else 0.0


class SSVEngine:
    """Single-sequence (B=1 per stream) speculative serving engine.

    ``instrument=True`` adds per-phase wall times (draft / verify+accept /
    commit) to StepStats by blocking between phases — measurement only, it
    serializes the step and should stay off in production paths.
    """

    def __init__(self, target_params, target_cfg: ModelConfig, draft_params,
                 draft_cfg: ModelConfig, serve_cfg: ServeConfig, planner=None,
                 rng_seed: int = 0, instrument: bool = False):
        if getattr(planner, "is_batch_planner", False):
            raise ValueError(
                "BatchPlanner plans bucket-local execution groups over a "
                "batch; the single-stream SSVEngine takes a RuntimePlanner — "
                "use BatchedSSVEngine for bucketed serving")
        self.tp, self.tcfg = target_params, target_cfg
        self.dp, self.dcfg = draft_params, draft_cfg
        self.serve = serve_cfg
        self.planner = planner
        self.rng = np.random.default_rng(rng_seed)
        self.instrument = instrument
        self.t_caches = None
        self.d_caches = None
        self.pending: Optional[int] = None
        self.prompt_len = 0
        self.committed_len = 0   # host-side mirror of caches["length"]
        self.store = _resolve_store(serve_cfg, target_cfg)
        self.allocator: Optional[kvstore.PageAllocator] = None
        if self.store.is_paged:
            self._page_size = self.store.page_size
            self._max_pages = self.store.logical_pages(serve_cfg.max_context,
                                                       self._page_size)

    # -------------------------------------------------------------- setup
    def start(self, prompt_tokens: np.ndarray, max_new_tokens: int = 0):
        """prompt_tokens: (S,) — prefill both models; the last prompt token
        becomes the pending root of the first tree. Under the paged store the
        prefilled KV is re-homed into freshly allocated pages sized for
        prompt + ``max_new_tokens`` (default: the serve config budget) +
        speculative headroom."""
        toks = jnp.asarray(prompt_tokens, jnp.int32)[None]
        max_len = self.serve.max_context
        # prefill everything except the last token — it becomes the pending root
        _, self.t_caches = jit_prefill(self.tcfg, max_len)(self.tp, toks[:, :-1])
        _, self.d_caches = jit_prefill(self.dcfg, max_len)(self.dp, toks[:, :-1])
        if self.store.is_paged:
            need = request_pages(self.serve, self.planner, self._page_size,
                                 self._max_pages, len(prompt_tokens),
                                 max_new_tokens)
            self.allocator = kvstore.PageAllocator(
                self.store.resolved_num_pages(1, self._max_pages))
            pg = self.allocator.alloc(need)
            if pg is None:
                raise ValueError(
                    f"kv_num_pages={self.allocator.num_pages} pages cannot "
                    f"hold this request ({need} pages needed)")
            row = np.full((self._max_pages,), -1, np.int32)
            row[:need] = pg
            rowj = jnp.asarray(row)

            def rehome(cfg, dense_caches):
                segs = model.init_caches(cfg, 1, max_len, self.store)["segments"]
                segs = kvstore.admit_row_paged(segs, dense_caches["segments"],
                                               jnp.int32(0), rowj)
                return {"segments": segs, "length": dense_caches["length"],
                        "pages": rowj[None]}

            self.t_caches = rehome(self.tcfg, self.t_caches)
            self.d_caches = rehome(self.dcfg, self.d_caches)
        self.pending = int(prompt_tokens[-1])
        self.prompt_len = len(prompt_tokens)
        self.committed_len = self.prompt_len - 1
        if self.planner is not None:
            self.planner.begin_request(context_len=self.prompt_len)

    # -------------------------------------------------------------- one step
    def step(self, strategy: Optional[SSVConfig] = None) -> Tuple[List[int], StepStats]:
        ssv = strategy or (self.planner.current() if self.planner else self.serve.ssv)
        topo = build_topology(ssv.tree_depth, ssv.tree_width, ssv.traversal,
                              ssv.tree_budget)
        greedy = self.serve.temperature == 0.0
        t0 = time.perf_counter()
        phases: Optional[Dict[str, float]] = {} if self.instrument else None
        pending = jnp.asarray([self.pending], jnp.int32)

        dverify = jit_verify(self.dcfg, None)
        tokens, node_q, d_updates = draft_lib.expand_tree(
            lambda caches, tk, pos, tm, par: dverify(self.dp, caches, tk, pos, tm, par),
            self.dcfg, self.d_caches, topo, pending,
            temperature=self.serve.temperature)
        if phases is not None:
            jax.block_until_ready(tokens)
            phases["draft"] = time.perf_counter() - t0

        T = topo.num_nodes
        step_fn = jit_verify_accept(self.tcfg, ssv, greedy, self.serve.temperature)
        t1 = time.perf_counter()
        if greedy:
            self.t_caches, path, out_tokens, bonus, n_acc = step_fn(
                self.tp, self.t_caches, tokens)
        else:
            accept_u, bonus_u = accept_lib.draw_uniforms(topo, self.rng)
            self.t_caches, path, out_tokens, bonus, n_acc = step_fn(
                self.tp, self.t_caches, tokens,
                node_q, jnp.asarray(accept_u, jnp.float32),
                jnp.float32(bonus_u))
        if phases is not None:
            jax.block_until_ready(out_tokens)
            phases["verify_accept"] = time.perf_counter() - t1

        t2 = time.perf_counter()
        # draft commit consumes the on-device path — no host round-trip
        self.d_caches = jit_commit(self.dcfg)(
            self.dp, self.d_caches, d_updates, path[None], (n_acc + 1)[None])
        # the ONLY device->host transfer of the step: a few ints
        n = int(n_acc)
        emitted = np.asarray(out_tokens[: n + 1])
        self.pending = int(emitted[-1])
        self.committed_len += n + 1
        if phases is not None:
            jax.block_until_ready(jax.tree.leaves(self.d_caches))
            phases["commit"] = time.perf_counter() - t2

        dt = time.perf_counter() - t0
        stats = StepStats(accepted=n, emitted=n + 1, latency_s=dt, gamma=T - 1,
                          strategy=ssv, host_elems=emitted.size + 2,
                          phases=phases)
        if self.planner is not None:
            self.planner.observe(accepted=n, latency_s=dt)
        return [int(t) for t in emitted], stats

    # -------------------------------------------------------------- generate
    def generate(self, prompt_tokens: np.ndarray, max_new_tokens: int = 0,
                 eos_id: int = -1) -> GenerationResult:
        max_new = max_new_tokens or self.serve.max_new_tokens
        self.start(np.asarray(prompt_tokens), max_new_tokens=max_new)
        out: List[int] = []
        steps: List[StepStats] = []
        while len(out) < max_new:
            new_toks, st = self.step()
            steps.append(st)
            for t in new_toks:
                out.append(int(t))
                if t == eos_id or len(out) >= max_new:
                    break
            if out and out[-1] == eos_id:
                break
            # host-tracked committed length — no device sync in the loop
            if self.committed_len + 2 * (st.gamma + 2) >= self.serve.max_context:
                break
        return GenerationResult(tokens=np.asarray(out), steps=steps)

    def kv_cache_bytes(self) -> int:
        """Raw-KV footprint of the live caches (both models)."""
        total = 0
        for caches in (self.t_caches, self.d_caches):
            if caches is not None:
                total += kvstore.kv_cache_bytes(caches["segments"])
        return total

    def kernel_cache_stats(self) -> Dict[str, int]:
        """Kernel-layer cache hit/miss counters (process-wide)."""
        return kernel_cache_stats()


# ------------------------------------------------------------ batched engine
@dataclasses.dataclass
class BatchGenerationResult:
    """Per-request outputs plus aggregate throughput of a batched generate."""
    results: List[GenerationResult]
    steps: int
    wall_s: float

    @property
    def total_tokens(self) -> int:
        return int(sum(len(r.tokens) for r in self.results))

    @property
    def aggregate_throughput(self) -> float:
        return self.total_tokens / self.wall_s if self.wall_s > 0 else 0.0


@functools.lru_cache(maxsize=32)
def jit_batched_step(tcfg: ModelConfig, dcfg: ModelConfig, ssv: SSVConfig,
                     greedy: bool, temperature: float,
                     store: kvstore.KVStoreConfig = kvstore.DENSE):
    """One fully fused, batch-vectorized SSV step.

    The entire draft-expand → tree-verify → accept → commit chain is traced
    once for a single request row (per-row scalar length, exactly the
    single-stream semantics) and vmapped over the request batch, then jitted
    with both models' cache pytrees donated. Per-row lengths diverge freely;
    an ``active`` flag turns finished rows into no-op commits.

    Continuous batching rides on per-row ADMISSION masks: a row with
    ``admit_mask`` set had a fresh KV prefix written into its cache row by
    the per-slot re-prefill (see ``admit_row_segments``), and this launch
    resets its device length and pending root from ``admit_len`` /
    ``admit_pending`` before stepping — so one launch serves a mix of
    freshly-admitted and mid-generation rows without touching other rows.

    Greedy signature:     f(tp, dp, t_segs, t_len, d_segs, d_len, pending,
                            active, admit_mask, admit_len, admit_pending)
    Stochastic signature: f(..., admit_pending, accept_u (R,rounds,kmax),
                            bonus_u (R,))
      -> (t_segs', t_len', d_segs', d_len', tokens (R, pad+1), n_acc (R,))
    where segs are the caches' "segments" pytrees with leaf batch axis 1.

    Paged store: the signature gains ``pages`` (R, max_pages) after
    ``d_len``. Raw-KV leaves of both segs are the models' shared page pools
    (no batch axis — every row reads them through its page-table row inside
    the vmap), so the per-row trace runs ``commit_paged_prepare`` only and
    the pool scatters are issued once at batch level, where rows cannot
    alias (the allocator never double-assigns a page).
    """
    topo = build_topology(ssv.tree_depth, ssv.tree_width, ssv.traversal,
                          ssv.tree_budget)
    depths = jnp.asarray(topo.depths)
    tmask = jnp.asarray(topo.mask)
    parents = jnp.asarray(topo.parents)
    child_mat = jnp.asarray(children_matrix(topo))
    maxd = int(topo.depths.max()) if topo.num_nodes else 0
    T = topo.num_nodes

    if store.is_paged:
        def row_prep(tp, dp, t_segs, t_len, d_segs, d_len, pages_row, pending,
                     active, accept_fn):
            rebatch = lambda segs: kvstore.map_segments(
                segs, lambda a: a, lambda a: a[:, None])
            t_caches = {"segments": rebatch(t_segs), "length": t_len,
                        "pages": pages_row[None]}
            d_caches = {"segments": rebatch(d_segs), "length": d_len,
                        "pages": pages_row[None]}
            tokens, node_q, d_updates = draft_lib.expand_tree(
                lambda caches, tk, pos, tm, par: model.verify_step(
                    dp, dcfg, caches, tk, pos, tm, par, None),
                dcfg, d_caches, topo, pending[None], temperature=temperature)
            positions = (depths[None] + t_len).astype(jnp.int32)
            logits, t_updates = model.verify_step(
                tp, tcfg, t_caches, tokens, positions, tmask[None], parents, ssv)
            path, out_tokens, bonus, n_acc = accept_fn(tokens[0], logits[0],
                                                       node_q[0])
            n_commit = jnp.where(active, n_acc + 1, 0)[None]
            t_prep, t_new_len = model.commit_paged_prepare(
                tp, tcfg, t_caches, t_updates, path[None], n_commit)
            d_prep, d_new_len = model.commit_paged_prepare(
                dp, dcfg, d_caches, d_updates, path[None], n_commit)
            strip = lambda tree: jax.tree.map(lambda a: a[:, 0], tree)
            return (strip(t_prep), t_new_len, strip(d_prep), d_new_len,
                    out_tokens, n_acc)

        if greedy:
            def row_step(tp, dp, t_segs, t_len, d_segs, d_len, pages_row,
                         pending, active):
                return row_prep(tp, dp, t_segs, t_len, d_segs, d_len,
                                pages_row, pending, active, lambda tk, lg, _q:
                                accept_lib.greedy_tree_accept_device(
                                    child_mat, maxd, tk, lg))
            extra_axes = ()
        else:
            def row_step(tp, dp, t_segs, t_len, d_segs, d_len, pages_row,
                         pending, active, accept_u, bonus_u):
                return row_prep(tp, dp, t_segs, t_len, d_segs, d_len,
                                pages_row, pending, active, lambda tk, lg, q:
                                accept_lib.stochastic_tree_accept_device(
                                    child_mat, maxd, tk, lg, q, accept_u,
                                    bonus_u, temperature))
            extra_axes = (0, 0)

        def f(tp, dp, t_segs, t_len, d_segs, d_len, pages, pending, active,
              admit_mask, admit_len, admit_pending, *rest):
            t_len = jnp.where(admit_mask, admit_len, t_len)
            d_len = jnp.where(admit_mask, admit_len, d_len)
            pending = jnp.where(admit_mask, admit_pending, pending)
            # pool leaves are shared (unmapped); every other cache leaf is
            # row-batched on axis 1 as in the dense step
            t_axes = kvstore.map_segments(t_segs, lambda _: None, lambda _: 1)
            d_axes = kvstore.map_segments(d_segs, lambda _: None, lambda _: 1)
            vstep = jax.vmap(row_step,
                             in_axes=(None, None, t_axes, 0, d_axes, 0, 0, 0, 0)
                             + extra_axes,
                             out_axes=(1, 0, 1, 0, 0, 0))
            (t_prep, t_new_len, d_prep, d_new_len, out_tokens,
             n_acc) = vstep(tp, dp, t_segs, t_len, d_segs, d_len, pages,
                            pending, active, *rest)
            n_commit = jnp.where(active, n_acc + 1, 0)
            new_t = model.commit_apply_paged(t_segs, t_prep, pages, t_len,
                                             n_commit)
            new_d = model.commit_apply_paged(d_segs, d_prep, pages, d_len,
                                             n_commit)
            return new_t, t_new_len, new_d, d_new_len, out_tokens, n_acc

        return jax.jit(f, donate_argnums=(2, 3, 4, 5))

    def row_core(tp, dp, t_segs, t_len, d_segs, d_len, pending, active,
                 accept_fn):
        t_caches = {"segments": jax.tree.map(lambda a: a[:, None], t_segs),
                    "length": t_len}
        d_caches = {"segments": jax.tree.map(lambda a: a[:, None], d_segs),
                    "length": d_len}
        tokens, node_q, d_updates = draft_lib.expand_tree(
            lambda caches, tk, pos, tm, par: model.verify_step(
                dp, dcfg, caches, tk, pos, tm, par, None),
            dcfg, d_caches, topo, pending[None], temperature=temperature)
        positions = (depths[None] + t_len).astype(jnp.int32)
        logits, t_updates = model.verify_step(
            tp, tcfg, t_caches, tokens, positions, tmask[None], parents, ssv)
        path, out_tokens, bonus, n_acc = accept_fn(tokens[0], logits[0],
                                                   node_q[0])
        n_commit = jnp.where(active, n_acc + 1, 0)[None]
        new_t = model.commit(tp, tcfg, t_caches, t_updates, path[None], n_commit)
        new_d = model.commit(dp, dcfg, d_caches, d_updates, path[None], n_commit)
        return (jax.tree.map(lambda a: a[:, 0], new_t["segments"]),
                new_t["length"],
                jax.tree.map(lambda a: a[:, 0], new_d["segments"]),
                new_d["length"], out_tokens, n_acc)

    if greedy:
        def row_step(tp, dp, t_segs, t_len, d_segs, d_len, pending, active):
            return row_core(tp, dp, t_segs, t_len, d_segs, d_len, pending,
                            active, lambda tk, lg, _q:
                            accept_lib.greedy_tree_accept_device(
                                child_mat, maxd, tk, lg))
        in_axes = (None, None, 1, 0, 1, 0, 0, 0)
    else:
        def row_step(tp, dp, t_segs, t_len, d_segs, d_len, pending, active,
                     accept_u, bonus_u):
            return row_core(tp, dp, t_segs, t_len, d_segs, d_len, pending,
                            active, lambda tk, lg, q:
                            accept_lib.stochastic_tree_accept_device(
                                child_mat, maxd, tk, lg, q, accept_u,
                                bonus_u, temperature))
        in_axes = (None, None, 1, 0, 1, 0, 0, 0, 0, 0)

    vstep = jax.vmap(row_step, in_axes=in_axes, out_axes=(1, 0, 1, 0, 0, 0))

    def f(tp, dp, t_segs, t_len, d_segs, d_len, pending, active,
          admit_mask, admit_len, admit_pending, *rest):
        t_len = jnp.where(admit_mask, admit_len, t_len)
        d_len = jnp.where(admit_mask, admit_len, d_len)
        pending = jnp.where(admit_mask, admit_pending, pending)
        return vstep(tp, dp, t_segs, t_len, d_segs, d_len, pending, active,
                     *rest)

    return jax.jit(f, donate_argnums=(2, 3, 4, 5))


@functools.partial(jax.jit, donate_argnums=(0,))
def admit_row_segments(batch_segs, row_segs, row):
    """Per-slot re-prefill landing: write a freshly-prefilled single-request
    cache (leaf batch axis of size 1) into row ``row`` of the batched cache
    pytree, in place (the batch buffers are donated — no copy of the other
    rows). ``row`` is a traced argument, so one compile serves every slot."""
    return jax.tree.map(
        lambda b, s: jax.lax.dynamic_update_slice_in_dim(
            b, s.astype(b.dtype), row, axis=1),
        batch_segs, row_segs)


# ------------------------------------------------- bucket-local group steps
class StepCompileCache:
    """Explicit AOT compile cache for the bucketed engine's fused group
    steps, keyed by (strategy, padded group size).

    jax.jit's implicit trace cache would retrace on first contact with every
    new (strategy, shape) pair — a multi-second stall that lands mid-serve
    exactly when the runtime guard switches a bucket's strategy. Entries here
    are ``.lower(...).compile()`` executables, populated either lazily (a
    recorded miss) or up front by ``BatchedSSVEngine.warmup``; hit/miss
    counts surface in the engine's kernel-cache metrics."""

    def __init__(self):
        self._exe: Dict = {}
        self.hits = 0
        self.misses = 0

    @property
    def size(self) -> int:
        return len(self._exe)

    def __contains__(self, key) -> bool:
        return key in self._exe

    def get_or_build(self, key, build):
        exe = self._exe.get(key)
        if exe is None:
            self.misses += 1
            exe = build()
            self._exe[key] = exe
        else:
            self.hits += 1
        return exe

    def stats(self) -> Dict[str, int]:
        return {"step_cache_hits": self.hits,
                "step_cache_misses": self.misses,
                "step_cache_cached": len(self._exe)}


@jax.jit
def _take_leaves(leaves, idx):
    """One fused dispatch gathering batch rows ``idx`` (axis 1) out of a
    list of row-batched cache leaves."""
    return [jnp.take(a, idx, axis=1) for a in leaves]


@functools.partial(jax.jit, donate_argnums=(0,), static_argnums=(3,))
def _scatter_leaves(batch_leaves, group_leaves, ridx, r: int):
    """One fused, donated dispatch writing the first ``r`` group rows back
    into the batch leaves at rows ``ridx`` (axis 1). Padded duplicate rows
    past ``r`` are dropped — scattering them would race the real row."""
    return [b.at[:, ridx].set(
                jax.lax.slice_in_dim(g, 0, r, axis=1).astype(b.dtype))
            for b, g in zip(batch_leaves, group_leaves)]


def _pool_flags(segs, store: kvstore.KVStoreConfig):
    """Per-leaf booleans marking the paged store's shared-pool leaves (flat
    order aligned with ``jax.tree.flatten(segs)``)."""
    if not store.is_paged:
        return None
    flags = kvstore.map_segments(segs, lambda _: True, lambda _: False)
    return jax.tree.flatten(flags)[0]


def gather_group_segments(segs, idx, store: kvstore.KVStoreConfig):
    """Gather one execution group's rows out of a batched cache pytree.

    Dense: every leaf is row-batched on axis 1 — the group's KV rows are
    copied out in one fused dispatch (and written back by
    ``scatter_group_segments``). Paged: the shared page pool passes through
    BY REFERENCE — no KV copy; only the row-batched leaves (cmp / recurrent
    state, 16x smaller than raw KV) are gathered, and each row reads the
    pool through its own page-table row."""
    flat, treedef = jax.tree.flatten(segs)
    pool = _pool_flags(segs, store)
    if pool is None:
        return jax.tree.unflatten(treedef, _take_leaves(flat, idx))
    rows = [a for a, p in zip(flat, pool) if not p]
    taken = iter(_take_leaves(rows, idx))
    return jax.tree.unflatten(
        treedef, [a if p else next(taken) for a, p in zip(flat, pool)])


def scatter_group_segments(batch_segs, group_segs, ridx, r: int,
                           store: kvstore.KVStoreConfig):
    """Land a stepped group back into the batched cache pytree (only the
    ``r`` real rows; padding duplicates are dropped). Row-batched leaves are
    written with one fused, donated dispatch. The paged pool leaf is
    REPLACED wholesale: the group step committed into the shared (donated)
    pool in place, so its output is the batch's new pool — the stale pool
    leaf inside ``batch_segs`` was consumed by that donation and is never
    touched here."""
    flat_b, treedef = jax.tree.flatten(batch_segs)
    flat_g = jax.tree.flatten(group_segs)[0]
    pool = _pool_flags(batch_segs, store)
    if pool is None:
        return jax.tree.unflatten(treedef,
                                  _scatter_leaves(flat_b, flat_g, ridx, r))
    rows_b = [a for a, p in zip(flat_b, pool) if not p]
    rows_g = [a for a, p in zip(flat_g, pool) if not p]
    written = iter(_scatter_leaves(rows_b, rows_g, ridx, r))
    return jax.tree.unflatten(
        treedef, [g if p else next(written)
                  for g, p in zip(flat_g, pool)])


class BatchedSSVEngine:
    """True multi-request SSV engine: one device launch per step serves the
    whole batch, with per-request committed lengths, per-request acceptance,
    and completion masks. Requests are prefilled independently (exact
    per-prompt caches) and their cache pytrees stacked along the batch axis.

    Continuous batching: ``start_empty`` allocates a fixed number of batch
    slots up front; ``admit`` re-prefills one request into a freed slot
    (donated in-place row write + per-row admission mask on the next fused
    step) without perturbing in-flight rows; ``serve_continuous`` runs the
    full queue → admit → step loop against a ``schedule.Scheduler``.

    The verification strategy is shared within one fused launch (the tree
    topology must be uniform for vectorization). A ``RuntimePlanner``
    observes the mean acceptance over active rows and switches ONE strategy
    for the whole batch; a ``planner_lib.BatchPlanner`` instead partitions
    the live slots into context-regime execution groups and
    ``serve_continuous`` launches one fused ``step_group`` per group under
    that bucket's profile strategy — mixed-length batches stop paying a
    one-size-fits-all topology (see the bucketed paragraph on
    ``serve_continuous``).
    """

    def __init__(self, target_params, target_cfg: ModelConfig, draft_params,
                 draft_cfg: ModelConfig, serve_cfg: ServeConfig, planner=None,
                 rng_seed: int = 0):
        self.tp, self.tcfg = target_params, target_cfg
        self.dp, self.dcfg = draft_params, draft_cfg
        self.serve = serve_cfg
        self.planner = planner
        self.rng = np.random.default_rng(rng_seed)
        self.t_segs = self.d_segs = None
        self.t_len = self.d_len = None
        self.pending: Optional[np.ndarray] = None
        self.committed_len: Optional[np.ndarray] = None  # host-side (R,)
        self.batch = 0
        # pending per-row admission resets, consumed by the next step()
        self._admit_mask: Optional[np.ndarray] = None
        self._admit_len: Optional[np.ndarray] = None
        self._admit_pending: Optional[np.ndarray] = None
        # KV store backend: one page pool + one page table serve BOTH models
        # (same logical token positions per row; per-model pools differ only
        # in head geometry / layer count)
        self.store = _resolve_store(serve_cfg, target_cfg)
        self.allocator: Optional[kvstore.PageAllocator] = None
        self.pages: Optional[np.ndarray] = None          # (R, max_pages) host
        self._slot_pages: Dict[int, np.ndarray] = {}
        if self.store.is_paged:
            self._page_size = self.store.page_size
            self._max_pages = self.store.logical_pages(serve_cfg.max_context,
                                                       self._page_size)
        # bucket-local execution groups: AOT-compiled per-(strategy, padded
        # group size) fused steps; see step_group / warmup
        self.step_cache = StepCompileCache()
        self._step_cache_slots: Optional[int] = None

    # -------------------------------------------------------------- setup
    def _planner_begin(self, context_len: int):
        """Reset the attached planner for a fresh serving run: a BatchPlanner
        resets its per-bucket guards, a RuntimePlanner re-seeds from the
        batch's context regime."""
        if self.planner is None:
            return
        if getattr(self.planner, "is_batch_planner", False):
            self.planner.begin_serve()
        else:
            self.planner.begin_request(context_len=context_len)

    def _max_gamma(self) -> int:
        return max_draft_gamma(self.serve, self.planner)

    def _step_headroom(self) -> int:
        return step_headroom(self.serve, self.planner)

    def _check_prompt(self, p: np.ndarray, what: str = "prompt"):
        if len(p) == 0:
            raise ValueError(f"{what} is empty — need at least 1 token")
        # the generate loops stop a row once committed_len + headroom reaches
        # max_context, but only AFTER its first step — a prompt admitted
        # without that headroom would let the first commit write past the
        # cache end (XLA clamps the slice -> silent KV corruption), so the
        # bound must hold at admission time, over every strategy the planner
        # could switch to.
        headroom = self._step_headroom()
        if len(p) - 1 + headroom > self.serve.max_context:
            raise ValueError(
                f"{what} has {len(p)} tokens, exceeding "
                f"max_context={self.serve.max_context} minus the "
                f"{headroom}-token speculative-step headroom; truncate the "
                f"prompt or raise ServeConfig.max_context")

    def _reset_admission(self, R: int):
        self._admit_mask = np.zeros((R,), bool)
        self._admit_len = np.zeros((R,), np.int32)
        self._admit_pending = np.zeros((R,), np.int32)

    # ------------------------------------------------------------ page math
    def pages_for(self, prompt_len: int, max_new_tokens: int = 0) -> int:
        """Full-life page reservation for one request — see
        ``request_pages`` (shared with the single-stream engine)."""
        return request_pages(self.serve, self.planner, self._page_size,
                             self._max_pages, prompt_len, max_new_tokens)

    def _free_slot_pages(self, slot: int):
        pg = self._slot_pages.pop(slot, None)
        if pg is not None:
            self.allocator.free(pg)
            self.pages[slot] = -1

    def kv_cache_bytes(self) -> int:
        """Raw-KV footprint of the serving caches (both models) — dense:
        slots x max_context rows; paged: the shared page pools."""
        return (kvstore.kv_cache_bytes(self.t_segs)
                + kvstore.kv_cache_bytes(self.d_segs))

    def kernel_cache_stats(self) -> Dict[str, int]:
        """Engine cache metrics next to ``kv_cache_bytes``: process-wide
        kernel build / layout caches plus this engine's group-step AOT
        compile cache."""
        stats = kernel_cache_stats()
        stats.update(self.step_cache.stats())
        return stats

    # --------------------------------------------- group-step compile cache
    def _padded_group_sizes(self) -> List[int]:
        """The batch sizes a group launch can take: powers of two up to the
        slot count (plus the slot count itself). Execution groups are padded
        up to the next size so the compile cache holds O(log slots) shapes
        per strategy instead of one per arbitrary group size."""
        sizes, g = [], 1
        while g < self.batch:
            sizes.append(g)
            g *= 2
        sizes.append(self.batch)
        return sizes

    def _group_step_specs(self, ssv: SSVConfig, g: int) -> List:
        """Abstract (shape, dtype) argument list of the fused step for a
        ``g``-row execution group — what ``.lower`` needs to AOT-compile it
        without touching real buffers. Derived from the live caches, so it
        matches ``step_group``'s gathered arguments exactly."""
        spec = lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
        row_spec = lambda a: jax.ShapeDtypeStruct(
            a.shape[:1] + (g,) + a.shape[2:], a.dtype)
        if self.store.is_paged:
            segs_spec = lambda segs: kvstore.map_segments(segs, spec, row_spec)
        else:
            segs_spec = lambda segs: jax.tree.map(row_spec, segs)
        ivec = jax.ShapeDtypeStruct((g,), jnp.int32)
        bvec = jax.ShapeDtypeStruct((g,), jnp.bool_)
        args = [jax.tree.map(spec, self.tp), jax.tree.map(spec, self.dp),
                segs_spec(self.t_segs), ivec, segs_spec(self.d_segs), ivec]
        if self.store.is_paged:
            args.append(jax.ShapeDtypeStruct((g, self._max_pages), jnp.int32))
        args += [ivec, bvec, bvec, ivec, ivec]
        if self.serve.temperature != 0.0:
            topo = build_topology(ssv.tree_depth, ssv.tree_width,
                                  ssv.traversal, ssv.tree_budget)
            maxd = int(topo.depths.max()) if topo.num_nodes else 0
            kmax = max(1, children_matrix(topo).shape[1])
            args.append(jax.ShapeDtypeStruct((g, maxd + 1, kmax), jnp.float32))
            args.append(jax.ShapeDtypeStruct((g,), jnp.float32))
        return args

    def _compiled_group_step(self, ssv: SSVConfig, g: int):
        """The AOT-compiled fused step for a ``g``-row group under ``ssv``,
        from the explicit compile cache (lazy-compile on miss)."""
        greedy = self.serve.temperature == 0.0
        key = (ssv, int(g))

        def build():
            fn = jit_batched_step(self.tcfg, self.dcfg, ssv, greedy,
                                  self.serve.temperature, self.store)
            return fn.lower(*self._group_step_specs(ssv, g)).compile()

        return self.step_cache.get_or_build(key, build)

    def warmup(self, num_slots: Optional[int] = None,
               strategies: Optional[Sequence[SSVConfig]] = None) -> int:
        """Opt-in AOT warmup: compile the fused group step for every
        (strategy, padded group size) bucketed serving can launch, so a
        mid-serve strategy switch — or a group size first seen mid-flight —
        lands on a ready executable instead of stalling the whole batch on a
        retrace. ``strategies`` defaults to the attached BatchPlanner's
        reachable set (per bucket: the top rank plus every refinement hop the
        guard can take). Returns the number of executables compiled."""
        if strategies is None:
            if not getattr(self.planner, "is_batch_planner", False):
                raise ValueError(
                    "warmup compiles the bucketed group-step cache: attach a "
                    "planner_lib.BatchPlanner (profile-backed) or pass the "
                    "strategies to compile explicitly")
            strategies = self.planner.reachable_strategies()
        if self.t_segs is None or (num_slots is not None
                                   and num_slots != self.batch):
            self.start_empty(num_slots or self.serve.max_batch)
        before = self.step_cache.size
        for ssv in strategies:
            for g in self._padded_group_sizes():
                self._compiled_group_step(ssv, g)
        return self.step_cache.size - before

    def start(self, prompts: Sequence[np.ndarray]):
        R = len(prompts)
        if R < 1:
            raise ValueError("prompt list is empty — nothing to serve")
        prompts = [np.asarray(p) for p in prompts]
        for i, p in enumerate(prompts):
            self._check_prompt(p, what=f"prompt {i}")
        if self.store.is_paged:
            # one code path for every paged admission: empty slots + the
            # per-slot admit that allocates the row's pages
            self.start_empty(R)
            for i, p in enumerate(prompts):
                self.admit(i, p)
            self._planner_begin(int(np.max([len(p) for p in prompts])))
            return
        max_len = self.serve.max_context
        t_parts, d_parts = [], []
        for p in prompts:
            toks = jnp.asarray(np.asarray(p), jnp.int32)[None]
            _, tc = jit_prefill(self.tcfg, max_len)(self.tp, toks[:, :-1])
            _, dc = jit_prefill(self.dcfg, max_len)(self.dp, toks[:, :-1])
            t_parts.append(tc)
            d_parts.append(dc)

        def stack(parts):
            segs = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                                *[c["segments"] for c in parts])
            length = jnp.stack([c["length"] for c in parts])
            return segs, length

        self.t_segs, self.t_len = stack(t_parts)
        self.d_segs, self.d_len = stack(d_parts)
        self.pending = np.array([int(p[-1]) for p in prompts], np.int32)
        self.committed_len = np.array([len(p) - 1 for p in prompts], np.int64)
        self.batch = R
        self._reset_admission(R)
        self._planner_begin(int(np.max([len(p) for p in prompts])))

    def start_empty(self, num_slots: int):
        """Allocate ``num_slots`` empty batch slots (zeroed caches, length 0).
        Every request — including the first wave — then enters through
        ``admit``, so admitted-mid-flight rows share one code path."""
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        if self.store.is_paged and self._step_cache_slots != num_slots:
            # the shared pool's physical size follows the slot count, so
            # group-step executables compiled for another slot count are
            # stale; dense group shapes are slot-count independent
            self.step_cache = StepCompileCache()
        self._step_cache_slots = num_slots
        max_len = self.serve.max_context
        self.t_segs = model.init_caches(self.tcfg, num_slots, max_len,
                                        self.store)["segments"]
        self.d_segs = model.init_caches(self.dcfg, num_slots, max_len,
                                        self.store)["segments"]
        self.t_len = jnp.zeros((num_slots,), jnp.int32)
        self.d_len = jnp.zeros((num_slots,), jnp.int32)
        self.pending = np.zeros((num_slots,), np.int32)
        self.committed_len = np.zeros((num_slots,), np.int64)
        self.batch = num_slots
        self._reset_admission(num_slots)
        if self.store.is_paged:
            self.allocator = kvstore.PageAllocator(
                self.store.resolved_num_pages(num_slots, self._max_pages))
            self.pages = np.full((num_slots, self._max_pages), -1, np.int32)
            self._slot_pages = {}

    # -------------------------------------------------------------- admission
    def admit(self, slot: int, prompt: np.ndarray, max_new_tokens: int = 0):
        """Mid-flight admission: re-prefill ``prompt`` and write its fresh KV
        prefix into batch row ``slot`` (donated in-place row write — other
        rows' cache bytes are untouched). The device-side length and pending
        root of the row are reset by the NEXT fused step via the per-row
        admission mask, so admission costs one prefill plus one row write,
        and no extra device launch.

        Paged store: admission first allocates the request's pages (see
        ``pages_for`` — ``max_new_tokens`` bounds the reservation) and maps
        them into the slot's page-table row; the prompt KV is then scattered
        into those pages. Callers gate on free-page headroom (the scheduler
        does) — admitting past the pool raises rather than corrupting rows.

        NOTE: the prefill jit retraces per prompt LENGTH — the first
        admission at a previously-unseen length pays an XLA compile while
        in-flight rows wait. Serving traffic with many distinct lengths
        should bucket/pad prompts to a few lengths."""
        if not 0 <= slot < self.batch:
            raise ValueError(f"slot {slot} out of range for batch {self.batch}")
        prompt = np.asarray(prompt)
        self._check_prompt(prompt)
        max_len = self.serve.max_context
        toks = jnp.asarray(prompt, jnp.int32)[None]
        _, tc = jit_prefill(self.tcfg, max_len)(self.tp, toks[:, :-1])
        _, dc = jit_prefill(self.dcfg, max_len)(self.dp, toks[:, :-1])
        if self.store.is_paged:
            self._free_slot_pages(slot)      # stale mapping of a past tenant
            need = self.pages_for(len(prompt), max_new_tokens)
            pg = self.allocator.alloc(need)
            if pg is None:
                raise RuntimeError(
                    f"page pool exhausted admitting into slot {slot}: need "
                    f"{need} pages, {self.allocator.free_count} free — gate "
                    "admission on free-page headroom (Scheduler pages_for)")
            self._slot_pages[slot] = pg
            row = np.full((self._max_pages,), -1, np.int32)
            row[:need] = pg
            self.pages[slot] = row
            rowj = jnp.asarray(row)
            self.t_segs = kvstore.admit_row_paged(self.t_segs, tc["segments"],
                                                  jnp.int32(slot), rowj)
            self.d_segs = kvstore.admit_row_paged(self.d_segs, dc["segments"],
                                                  jnp.int32(slot), rowj)
        else:
            self.t_segs = admit_row_segments(self.t_segs, tc["segments"], slot)
            self.d_segs = admit_row_segments(self.d_segs, dc["segments"], slot)
        self._admit_mask[slot] = True
        self._admit_len[slot] = len(prompt) - 1
        self._admit_pending[slot] = int(prompt[-1])
        self.pending[slot] = int(prompt[-1])
        self.committed_len[slot] = len(prompt) - 1

    # -------------------------------------------------------------- one step
    def step(self, active: np.ndarray,
             strategy: Optional[SSVConfig] = None) -> Tuple[np.ndarray, np.ndarray]:
        """active: (R,) bool — rows to advance. Returns (tokens (R, pad+1),
        n_accepted (R,)); inactive rows commit nothing (length frozen). Rows
        admitted since the last step have their device length / pending root
        reset inside this same launch (per-row admission mask), so the launch
        serves freshly-admitted and mid-generation rows together."""
        if strategy is None and getattr(self.planner, "is_batch_planner",
                                        False):
            raise ValueError(
                "a BatchPlanner has no single batch-wide strategy — pass "
                "strategy= explicitly, or serve through serve_continuous / "
                "step_group so each execution group gets its bucket's plan")
        ssv = strategy or (self.planner.current() if self.planner else self.serve.ssv)
        greedy = self.serve.temperature == 0.0
        step_fn = jit_batched_step(self.tcfg, self.dcfg, ssv, greedy,
                                   self.serve.temperature, self.store)
        args = [self.tp, self.dp, self.t_segs, self.t_len, self.d_segs,
                self.d_len]
        if self.store.is_paged:
            args.append(jnp.asarray(self.pages))
        args += [jnp.asarray(self.pending), jnp.asarray(active),
                 jnp.asarray(self._admit_mask),
                 jnp.asarray(self._admit_len, jnp.int32),
                 jnp.asarray(self._admit_pending, jnp.int32)]
        self._admit_mask = np.zeros_like(self._admit_mask)
        if not greedy:
            topo = build_topology(ssv.tree_depth, ssv.tree_width,
                                  ssv.traversal, ssv.tree_budget)
            us = [accept_lib.draw_uniforms(topo, self.rng)
                  for _ in range(self.batch)]
            args.append(jnp.asarray(np.stack([u for u, _ in us]), jnp.float32))
            args.append(jnp.asarray([b for _, b in us], jnp.float32))
        (self.t_segs, self.t_len, self.d_segs, self.d_len, out_tokens,
         n_acc) = step_fn(*args)
        # per-step host transfer: (R, pad+1) token ids + (R,) counts
        toks_np = np.asarray(out_tokens)
        n_np = np.asarray(n_acc)
        live = np.asarray(active, bool)
        self.pending = np.where(live, toks_np[np.arange(self.batch), n_np],
                                self.pending).astype(np.int32)
        self.committed_len = self.committed_len + np.where(live, n_np + 1, 0)
        return toks_np, n_np

    def step_group(self, rows: Sequence[int],
                   strategy: SSVConfig) -> Tuple[np.ndarray, np.ndarray]:
        """Advance one bucket-local execution group: gather ``rows`` out of
        the batch, run one fused step under ``strategy`` (from the AOT
        compile cache), scatter the results back. Every listed row is
        stepped (the per-row admission resets of freshly-admitted rows are
        consumed exactly like ``step``); rows outside the group are
        untouched — their cache bytes, lengths, and pending roots stay
        byte-identical, so different groups can run different strategies in
        the same serving round.

        The group is padded to the next cached group size with an INACTIVE
        duplicate of the first row (no-op commit; outputs dropped at
        scatter), keeping compiled shapes to O(log slots) per strategy. The
        paged store's page pool is threaded through shared and donated — no
        KV copy; dense groups pay one gather + one scatter of their rows.

        Returns (tokens (r, pad+1), n_accepted (r,)) aligned with ``rows``.
        """
        rows = [int(s) for s in rows]
        if not rows:
            raise ValueError("empty execution group — nothing to step")
        if len(set(rows)) != len(rows):
            raise ValueError(f"duplicate rows in execution group {rows}")
        for s in rows:
            if not 0 <= s < self.batch:
                raise ValueError(f"row {s} out of range for batch {self.batch}")
        r = len(rows)
        # fast path: a group covering the whole batch (the common case under
        # the bucket-homogeneous admission policy) steps the engine caches
        # directly — donated in place, no gather/scatter at all
        full = rows == list(range(self.batch))
        g = r if full else next(s for s in self._padded_group_sizes()
                                if s >= r)
        pad_rows = rows + [rows[0]] * (g - r)
        active = np.zeros((g,), bool)
        active[:r] = True
        admit_mask = self._admit_mask[pad_rows].copy()
        admit_mask[r:] = False           # pads never reset the real row
        admit_len = np.asarray(self._admit_len[pad_rows], np.int32)
        admit_pending = np.asarray(self._admit_pending[pad_rows], np.int32)
        step_fn = self._compiled_group_step(strategy, g)
        if full:
            t_grp, d_grp = self.t_segs, self.d_segs
            t_len_in, d_len_in = self.t_len, self.d_len
        else:
            idx = jnp.asarray(np.asarray(pad_rows, np.int32))
            t_grp = gather_group_segments(self.t_segs, idx, self.store)
            d_grp = gather_group_segments(self.d_segs, idx, self.store)
            t_len_in = jnp.take(self.t_len, idx)
            d_len_in = jnp.take(self.d_len, idx)
        args = [self.tp, self.dp, t_grp, t_len_in, d_grp, d_len_in]
        if self.store.is_paged:
            args.append(jnp.asarray(self.pages[pad_rows]))
        args += [jnp.asarray(self.pending[pad_rows]), jnp.asarray(active),
                 jnp.asarray(admit_mask), jnp.asarray(admit_len),
                 jnp.asarray(admit_pending)]
        self._admit_mask[rows] = False   # consumed by this launch
        if self.serve.temperature != 0.0:
            topo = build_topology(strategy.tree_depth, strategy.tree_width,
                                  strategy.traversal, strategy.tree_budget)
            us = [accept_lib.draw_uniforms(topo, self.rng) for _ in range(g)]
            args.append(jnp.asarray(np.stack([u for u, _ in us]), jnp.float32))
            args.append(jnp.asarray([b for _, b in us], jnp.float32))
        (t_grp, t_len_g, d_grp, d_len_g, out_tokens, n_acc) = step_fn(*args)
        if full:
            self.t_segs, self.d_segs = t_grp, d_grp
            self.t_len, self.d_len = t_len_g, d_len_g
        else:
            ridx = jnp.asarray(np.asarray(rows, np.int32))
            self.t_segs = scatter_group_segments(self.t_segs, t_grp, ridx, r,
                                                 self.store)
            self.d_segs = scatter_group_segments(self.d_segs, d_grp, ridx, r,
                                                 self.store)
            self.t_len = self.t_len.at[ridx].set(t_len_g[:r])
            self.d_len = self.d_len.at[ridx].set(d_len_g[:r])
        toks_np = np.asarray(out_tokens)[:r]
        n_np = np.asarray(n_acc)[:r]
        self.pending[rows] = toks_np[np.arange(r), n_np].astype(np.int32)
        self.committed_len[rows] = self.committed_len[rows] + n_np + 1
        return toks_np, n_np

    # -------------------------------------------------------------- generate
    def generate_batch(self, prompts: Sequence[np.ndarray],
                       max_new_tokens: int = 0,
                       eos_id: int = -1) -> BatchGenerationResult:
        """Drain-mode batched generation: every prompt is admitted at t=0
        into its own slot and the batch runs to completion. Sugar over
        ``serve_continuous`` (one slot per prompt, no queue), so both entry
        points share one stepping/harvest loop."""
        if len(prompts) < 1:
            raise ValueError("prompt list is empty — nothing to serve")
        res = self.serve_continuous(
            [np.asarray(p) for p in prompts], num_slots=len(prompts),
            max_new_tokens=max_new_tokens, eos_id=eos_id)
        return BatchGenerationResult(results=res.results, steps=res.steps,
                                     wall_s=res.wall_s)

    # -------------------------------------------------------------- continuous
    def serve_continuous(self, requests: Sequence, num_slots: int,
                         max_new_tokens: int = 0, eos_id: int = -1,
                         bucketed: Optional[bool] = None,
                         warmup: bool = False) -> "ContinuousServeResult":
        """Continuous-batching serve loop: admit queued requests into freed
        slots mid-flight instead of draining the batch between waves.

        ``requests``: ``schedule.Request`` objects (arrival times on the
        virtual fused-step clock) or raw prompt arrays (all arrive at t=0).
        Per-row generation semantics are identical to single-stream
        ``SSVEngine.generate`` — admission never perturbs in-flight rows
        (tests/test_engine_continuous.py asserts token equality).

        Bucketed mode (``bucketed=None`` auto-enables it when the attached
        planner is a ``planner_lib.BatchPlanner``): each round, the live
        slots are partitioned into context-regime execution groups and one
        fused group step runs per group under the profile's strategy for
        that (bucket, precision class) — a mixed-length batch no longer
        forces short-context rows onto a long-context tree topology. The
        scheduler switches to the bucket-homogeneous admission policy, and
        per-row token streams stay byte-identical to single-stream
        generation under the row's bucket strategy
        (tests/test_engine_bucketed.py). ``warmup=True`` AOT-compiles every
        reachable (strategy, group size) step before serving starts.
        """
        max_new_default = max_new_tokens or self.serve.max_new_tokens
        is_bp = bool(getattr(self.planner, "is_batch_planner", False))
        if bucketed is None:
            bucketed = is_bp
        if bucketed and not is_bp:
            raise ValueError(
                "bucketed serving assigns each execution group its profile "
                "strategy — attach a planner_lib.BatchPlanner (built from an "
                "offline Profile); got "
                f"{type(self.planner).__name__ if self.planner else 'no planner'}")
        if is_bp and not bucketed:
            raise ValueError("a BatchPlanner only drives bucketed serving; "
                             "pass bucketed=True (or leave it None)")
        if warmup and not bucketed:
            raise ValueError("warmup=True pre-compiles the bucketed "
                             "group-step cache; it needs bucketed serving")
        reqs: List[schedule_lib.Request] = []
        for i, r in enumerate(requests):
            if isinstance(r, schedule_lib.Request):
                reqs.append(r)
            else:
                reqs.append(schedule_lib.Request(req_id=i,
                                                 prompt=np.asarray(r)))
        if not reqs:
            raise ValueError("request list is empty — nothing to serve")
        if len({r.req_id for r in reqs}) != len(reqs):
            raise ValueError("duplicate req_id in request list — outputs are "
                             "keyed by req_id and must not merge")
        for r in reqs:   # fail fast, before any slot state exists
            self._check_prompt(np.asarray(r.prompt),
                               what=f"request {r.req_id} prompt")
        sched_kwargs = {}
        if bucketed:
            sched_kwargs = dict(
                policy="bucket",
                bucket_of=lambda r: self.planner.bucket_of(len(r.prompt)))
        if self.store.is_paged:
            total_pages = self.store.resolved_num_pages(num_slots,
                                                        self._max_pages)
            pages_of = lambda r: self.pages_for(
                len(r.prompt), r.max_new_tokens or max_new_default)
            for r in reqs:   # a request bigger than the POOL can never admit
                if pages_of(r) > total_pages:
                    raise ValueError(
                        f"request {r.req_id} needs {pages_of(r)} KV pages but "
                        f"the pool has {total_pages}; raise kv_num_pages or "
                        "shrink the prompt/token budget")
            sched = schedule_lib.Scheduler(
                num_slots, pages_for=pages_of,
                free_pages=lambda: self.allocator.free_count,
                total_pages=total_pages, **sched_kwargs)
        else:
            sched = schedule_lib.Scheduler(num_slots, **sched_kwargs)
        for r in reqs:
            sched.submit(r)
        self.start_empty(num_slots)
        if bucketed:
            self.planner.begin_serve()
            if warmup:
                self.warmup()
        elif self.planner is not None:
            self.planner.begin_request(
                context_len=int(max(len(r.prompt) for r in reqs)))

        outs: Dict[int, List[int]] = {r.req_id: [] for r in reqs}
        step_logs: Dict[int, List[StepStats]] = {r.req_id: [] for r in reqs}
        occupancy: List[float] = []
        page_occupancy: List[float] = []
        bucket_occ: List[Dict[int, float]] = []
        group_launches = 0
        # context stop bound sized for the LARGEST strategy the planner can
        # switch to (a switch lands one step after this check runs)
        stop_margin = self._step_headroom()
        clock = 0.0
        n_steps = 0
        t_start = time.time()
        budget = sum((r.max_new_tokens or max_new_default) for r in reqs)
        safety = 4 * budget + 16 * len(reqs) + 16

        def harvest(slot, n, toks_row, dt, gamma, ssv):
            """Account one stepped row: record stats, stream its new tokens,
            and finish/release the slot at eos / budget / context bound.
            Shared verbatim by the single-launch and bucketed paths."""
            req = sched.request_at(slot)
            out = outs[req.req_id]
            limit = req.max_new_tokens or max_new_default
            step_logs[req.req_id].append(StepStats(
                accepted=n, emitted=n + 1, latency_s=dt, gamma=gamma,
                strategy=ssv, host_elems=len(toks_row) + 1))
            finished = False
            for t in toks_row[: n + 1]:
                out.append(int(t))
                if int(t) == eos_id or len(out) >= limit:
                    finished = True
                    break
            if self.committed_len[slot] + stop_margin >= self.serve.max_context:
                finished = True
            if finished:
                sched.finish(slot, now=clock + 1.0)
                if self.store.is_paged:
                    self._free_slot_pages(slot)   # pages return to pool
                sched.release(slot)

        while not sched.idle():
            for slot, req in sched.admit(clock):
                self.admit(slot, req.prompt,
                           max_new_tokens=req.max_new_tokens or max_new_default)
                sched.mark_decoding(slot)
            active = sched.decoding_mask()
            if not active.any():
                # arrival gap (or page-gated head-of-line wait): jump the
                # virtual clock to the next arrival
                nxt = sched.next_arrival()
                clock = max(clock + 1.0,
                            float(nxt) if nxt is not None else clock + 1.0)
                continue
            occupancy.append(float(active.sum()) / num_slots)
            if self.store.is_paged:
                page_occupancy.append(sched.page_occupancy())
            if bucketed:
                bucket_occ.append(sched.bucket_occupancy())
                slot_buckets = {
                    int(s): self.planner.bucket_of(
                        len(sched.request_at(int(s)).prompt))
                    for s in np.nonzero(active)[0]}
                for bucket, rows in self.planner.plan(slot_buckets):
                    strat = self.planner.strategy_for(bucket)
                    gamma = build_topology(
                        strat.tree_depth, strat.tree_width, strat.traversal,
                        strat.tree_budget).num_nodes - 1
                    t0 = time.perf_counter()
                    toks_g, n_g = self.step_group(rows, strat)
                    dt = time.perf_counter() - t0
                    group_launches += 1
                    for j, slot in enumerate(rows):
                        harvest(slot, int(n_g[j]), toks_g[j], dt, gamma, strat)
                    self.planner.observe(bucket, accepted=float(np.mean(n_g)),
                                         latency_s=dt)
            else:
                ssv = (self.planner.current() if self.planner
                       else self.serve.ssv)
                gamma = build_topology(ssv.tree_depth, ssv.tree_width,
                                       ssv.traversal,
                                       ssv.tree_budget).num_nodes - 1
                t0 = time.perf_counter()
                toks, n_acc = self.step(active=active)
                dt = time.perf_counter() - t0
                accepted_active = []
                for slot in np.nonzero(active)[0]:
                    slot = int(slot)
                    n = int(n_acc[slot])
                    accepted_active.append(n)
                    harvest(slot, n, toks[slot], dt, gamma, ssv)
                if self.planner is not None and accepted_active:
                    self.planner.observe(
                        accepted=float(np.mean(accepted_active)),
                        latency_s=dt)
            clock += 1.0
            n_steps += 1
            if n_steps > safety:   # shapes guarantee progress; belt-and-braces
                break
        wall = time.time() - t_start
        results = [GenerationResult(tokens=np.asarray(outs[r.req_id]),
                                    steps=step_logs[r.req_id]) for r in reqs]
        # mean decoding-slot fraction per bucket over the stepped rounds
        bucket_means: Dict[int, float] = {}
        if bucket_occ:
            for b in sorted({b for occ in bucket_occ for b in occ}):
                bucket_means[b] = float(
                    np.mean([occ.get(b, 0.0) for occ in bucket_occ]))
        return ContinuousServeResult(results=results, requests=reqs,
                                     steps=n_steps, wall_s=wall,
                                     occupancy=occupancy,
                                     page_occupancy=page_occupancy,
                                     kv_bytes=self.kv_cache_bytes(),
                                     bucket_occupancy=bucket_means,
                                     group_launches=group_launches,
                                     kernel_cache=self.kernel_cache_stats())


@dataclasses.dataclass
class ContinuousServeResult:
    """Outputs + serving statistics of a continuous-batching run. ``results``
    aligns with the submitted request order; queue-delay / occupancy are in
    virtual fused-step units (deterministic, wall-clock-free)."""
    results: List[GenerationResult]
    requests: List["schedule_lib.Request"]
    steps: int
    wall_s: float
    occupancy: List[float]       # per-fused-step busy-slot fraction
    # paged KV store only: per-fused-step allocated-page fraction + the raw
    # KV footprint of the run's caches (pool bytes; dense: row bytes)
    page_occupancy: List[float] = dataclasses.field(default_factory=list)
    kv_bytes: int = 0
    # bucketed serving only: mean decoding-slot fraction per context bucket
    # and the number of fused group launches issued (== steps when every
    # round had one homogeneous group)
    bucket_occupancy: Dict[int, float] = dataclasses.field(default_factory=dict)
    group_launches: int = 0
    # kernel-layer + group-step cache hit/miss counters at run end
    kernel_cache: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def total_tokens(self) -> int:
        return int(sum(len(r.tokens) for r in self.results))

    @property
    def aggregate_throughput(self) -> float:
        return self.total_tokens / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self.occupancy)) if self.occupancy else 0.0

    @property
    def mean_page_occupancy(self) -> float:
        return float(np.mean(self.page_occupancy)) if self.page_occupancy else 0.0

    @property
    def peak_page_occupancy(self) -> float:
        return float(np.max(self.page_occupancy)) if self.page_occupancy else 0.0

    @property
    def mean_queue_delay_steps(self) -> float:
        delays = [r.queue_delay for r in self.requests
                  if r.queue_delay is not None]
        return float(np.mean(delays)) if delays else 0.0


# ------------------------------------------------------------ baselines
def autoregressive_decode(params, cfg: ModelConfig, prompt_tokens: np.ndarray,
                          max_new_tokens: int, max_context: int,
                          temperature: float = 0.0, seed: int = 0) -> GenerationResult:
    """Plain decode loop (the paper's 49 tok/s NSA baseline shape)."""
    toks = jnp.asarray(prompt_tokens, jnp.int32)[None]
    # prefill all but the last prompt token; the first decode step processes it
    _, caches = jit_prefill(cfg, max_context)(params, toks[:, :-1])
    step = jax.jit(lambda p, c, t: model.decode_step(p, cfg, c, t))
    rng = np.random.default_rng(seed)
    cur = jnp.asarray([[int(prompt_tokens[-1])]], jnp.int32)
    committed = len(prompt_tokens) - 1   # host-side length mirror, no sync
    out: List[int] = []
    steps: List[StepStats] = []
    for _ in range(max_new_tokens):
        t0 = time.perf_counter()
        logits, caches = step(params, caches, cur)
        lg = np.asarray(logits[0, 0], np.float32)
        if temperature == 0.0:
            nxt = int(lg.argmax())
        else:
            p = np.exp((lg - lg.max()) / temperature)
            nxt = int(rng.choice(len(p), p=p / p.sum()))
        dt = time.perf_counter() - t0
        out.append(nxt)
        steps.append(StepStats(accepted=0, emitted=1, latency_s=dt, gamma=0,
                               strategy=None))
        cur = jnp.asarray([[nxt]], jnp.int32)
        committed += 1
        if committed + 2 >= max_context:
            break
    return GenerationResult(tokens=np.asarray(out), steps=steps)
