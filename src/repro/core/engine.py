"""SSVEngine — the end-to-end draft → sparse-verify → accept serving loop
(paper Fig. 3), with pluggable verification strategy (θ_d, θ_s), precision
class P, and planner-driven prompt adaptation.

Per generation step:
  1. the planner supplies the active strategy (tree shape, traversal,
     grouping, refresh/reuse schedule);
  2. the draft model expands a rooted token tree under the pending token;
  3. the target verifies all nodes in one tree-masked pass — NSA layers run
     the refresh/reuse schedule and exact/approx grouped selection;
  4. host-side accept/reject picks the longest valid path + a bonus token;
  5. both models commit the accepted path's K/V (or recurrent states);
  6. step statistics (A_t, T_t) feed the planner's runtime guard.

All device computations are jitted and cached per (config, strategy, tree
topology) — fixed shapes, no recompilation inside a generation.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServeConfig, SSVConfig
from repro.core import accept as accept_lib
from repro.core import draft as draft_lib
from repro.core.tree import TreeTopology, build_topology, positions_for
from repro.models import model


# ------------------------------------------------------------ jit caches
@functools.lru_cache(maxsize=64)
def jit_verify(cfg: ModelConfig, ssv: Optional[SSVConfig]):
    def f(params, caches, tokens, positions, tmask, parents):
        return model.verify_step(params, cfg, caches, tokens, positions, tmask,
                                 parents, ssv)
    return jax.jit(f)


@functools.lru_cache(maxsize=64)
def jit_commit(cfg: ModelConfig):
    def f(params, caches, updates, accepted, n_accepted):
        return model.commit(params, cfg, caches, updates, accepted, n_accepted)
    return jax.jit(f)


@functools.lru_cache(maxsize=64)
def jit_prefill(cfg: ModelConfig, max_len: int):
    def f(params, tokens):
        return model.prefill(params, cfg, tokens, max_len)
    return jax.jit(f)


@dataclasses.dataclass
class StepStats:
    accepted: int          # draft tokens accepted (A_t excludes the bonus)
    emitted: int           # new tokens emitted this step (accepted + 1 bonus)
    latency_s: float       # T_t
    gamma: int             # draft tokens verified
    strategy: SSVConfig


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray
    steps: List[StepStats]

    @property
    def accepted_token_throughput(self) -> float:
        tot_t = sum(s.latency_s for s in self.steps)
        tot_e = sum(s.emitted for s in self.steps)
        return tot_e / tot_t if tot_t > 0 else 0.0

    @property
    def mean_accepted(self) -> float:
        return float(np.mean([s.accepted for s in self.steps])) if self.steps else 0.0


class SSVEngine:
    """Single-sequence (B=1 per stream) speculative serving engine."""

    def __init__(self, target_params, target_cfg: ModelConfig, draft_params,
                 draft_cfg: ModelConfig, serve_cfg: ServeConfig, planner=None,
                 rng_seed: int = 0):
        self.tp, self.tcfg = target_params, target_cfg
        self.dp, self.dcfg = draft_params, draft_cfg
        self.serve = serve_cfg
        self.planner = planner
        self.rng = np.random.default_rng(rng_seed)
        self.t_caches = None
        self.d_caches = None
        self.pending: Optional[int] = None
        self.prompt_len = 0

    # -------------------------------------------------------------- setup
    def start(self, prompt_tokens: np.ndarray):
        """prompt_tokens: (S,) — prefill both models; the last prompt token
        becomes the pending root of the first tree."""
        toks = jnp.asarray(prompt_tokens, jnp.int32)[None]
        max_len = self.serve.max_context
        # prefill everything except the last token — it becomes the pending root
        _, self.t_caches = jit_prefill(self.tcfg, max_len)(self.tp, toks[:, :-1])
        _, self.d_caches = jit_prefill(self.dcfg, max_len)(self.dp, toks[:, :-1])
        self.pending = int(prompt_tokens[-1])
        self.prompt_len = len(prompt_tokens)
        if self.planner is not None:
            self.planner.begin_request(context_len=self.prompt_len)

    # -------------------------------------------------------------- one step
    def step(self, strategy: Optional[SSVConfig] = None) -> Tuple[List[int], StepStats]:
        ssv = strategy or (self.planner.current() if self.planner else self.serve.ssv)
        topo = build_topology(ssv.tree_depth, ssv.tree_width, ssv.traversal,
                              ssv.tree_budget)
        t0 = time.perf_counter()
        pending = jnp.asarray([self.pending], jnp.int32)

        dverify = jit_verify(self.dcfg, None)
        tokens, node_q, d_updates = draft_lib.expand_tree(
            lambda caches, tk, pos, tm, par: dverify(self.dp, caches, tk, pos, tm, par),
            self.dcfg, self.d_caches, topo, pending,
            temperature=self.serve.temperature)

        T = topo.num_nodes
        prefix = self.t_caches["length"]
        positions = (jnp.asarray(positions_for(topo, 0))[None] + prefix).astype(jnp.int32)
        tmask = jnp.asarray(topo.mask)[None]
        parents = jnp.asarray(topo.parents)
        tverify = jit_verify(self.tcfg, ssv)
        logits, t_updates = tverify(self.tp, self.t_caches, tokens, positions,
                                    tmask, parents)

        logits_np = np.asarray(logits[0], np.float32)
        tokens_np = np.asarray(tokens[0])
        if self.serve.temperature == 0.0:
            res = accept_lib.greedy_tree_accept(topo, tokens_np, logits_np)
        else:
            res = accept_lib.stochastic_tree_accept(
                topo, tokens_np, logits_np, np.asarray(node_q[0], np.float32),
                self.rng, self.serve.temperature)

        pad_to = int(topo.depths.max()) + 1
        path = jnp.asarray(accept_lib.pad_path(res.path, pad_to))[None]
        n_acc = jnp.asarray([res.n_accepted + 1], jnp.int32)  # +1: pending root
        self.t_caches = jit_commit(self.tcfg)(self.tp, self.t_caches, t_updates,
                                              path, n_acc)
        self.d_caches = jit_commit(self.dcfg)(self.dp, self.d_caches, d_updates,
                                              path, n_acc)
        self.pending = res.bonus
        dt = time.perf_counter() - t0
        stats = StepStats(accepted=res.n_accepted, emitted=res.n_accepted + 1,
                          latency_s=dt, gamma=T - 1, strategy=ssv)
        if self.planner is not None:
            self.planner.observe(accepted=res.n_accepted, latency_s=dt)
        return list(res.tokens), stats

    # -------------------------------------------------------------- generate
    def generate(self, prompt_tokens: np.ndarray, max_new_tokens: int = 0,
                 eos_id: int = -1) -> GenerationResult:
        max_new = max_new_tokens or self.serve.max_new_tokens
        self.start(np.asarray(prompt_tokens))
        out: List[int] = []
        steps: List[StepStats] = []
        while len(out) < max_new:
            new_toks, st = self.step()
            steps.append(st)
            for t in new_toks:
                out.append(int(t))
                if t == eos_id or len(out) >= max_new:
                    break
            if out and out[-1] == eos_id:
                break
            if int(self.t_caches["length"]) + 2 * (st.gamma + 2) >= self.serve.max_context:
                break
        return GenerationResult(tokens=np.asarray(out), steps=steps)


# ------------------------------------------------------------ baselines
def autoregressive_decode(params, cfg: ModelConfig, prompt_tokens: np.ndarray,
                          max_new_tokens: int, max_context: int,
                          temperature: float = 0.0, seed: int = 0) -> GenerationResult:
    """Plain decode loop (the paper's 49 tok/s NSA baseline shape)."""
    toks = jnp.asarray(prompt_tokens, jnp.int32)[None]
    # prefill all but the last prompt token; the first decode step processes it
    _, caches = jit_prefill(cfg, max_context)(params, toks[:, :-1])
    step = jax.jit(lambda p, c, t: model.decode_step(p, cfg, c, t))
    rng = np.random.default_rng(seed)
    cur = jnp.asarray([[int(prompt_tokens[-1])]], jnp.int32)
    out: List[int] = []
    steps: List[StepStats] = []
    for _ in range(max_new_tokens):
        t0 = time.perf_counter()
        logits, caches = step(params, caches, cur)
        lg = np.asarray(logits[0, 0], np.float32)
        if temperature == 0.0:
            nxt = int(lg.argmax())
        else:
            p = np.exp((lg - lg.max()) / temperature)
            nxt = int(rng.choice(len(p), p=p / p.sum()))
        dt = time.perf_counter() - t0
        out.append(nxt)
        steps.append(StepStats(accepted=0, emitted=1, latency_s=dt, gamma=0,
                               strategy=None))
        cur = jnp.asarray([[nxt]], jnp.int32)
        if int(caches["length"]) + 2 >= max_context:
            break
    return GenerationResult(tokens=np.asarray(out), steps=steps)
