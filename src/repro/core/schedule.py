"""Refresh/reuse schedule calibration — training-free IndexCache-style greedy
search (paper §5.2, Table 1 footnote).

Given a target model and a calibration batch, greedily grow the set of REUSE
layers: at each round, tentatively add each remaining candidate layer and
measure the output-logit KL divergence against the all-refresh baseline on a
verification workload; keep the candidate with the smallest KL as long as it
stays under ``kl_budget``. Layer 0 is never a candidate (mandatory refresh).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


def kl_divergence(p_logits: np.ndarray, q_logits: np.ndarray) -> float:
    """Mean KL(p || q) over leading dims; logits (..., V)."""
    p_logits = p_logits.astype(np.float64)
    q_logits = q_logits.astype(np.float64)
    p_logits = p_logits - p_logits.max(-1, keepdims=True)
    q_logits = q_logits - q_logits.max(-1, keepdims=True)
    lp = p_logits - np.log(np.exp(p_logits).sum(-1, keepdims=True))
    lq = q_logits - np.log(np.exp(q_logits).sum(-1, keepdims=True))
    p = np.exp(lp)
    return float((p * (lp - lq)).sum(-1).mean())


def greedy_calibrate(eval_fn: Callable[[Tuple[int, ...]], np.ndarray],
                     num_layers: int, kl_budget: float = 0.02,
                     max_reuse: Optional[int] = None) -> Tuple[int, ...]:
    """eval_fn(schedule) -> verification logits for the calibration batch.

    Returns the calibrated REUSE-layer index tuple (sorted)."""
    baseline = eval_fn(())
    schedule: List[int] = []
    candidates = list(range(1, num_layers))
    max_reuse = max_reuse if max_reuse is not None else num_layers - 1
    while candidates and len(schedule) < max_reuse:
        best = None
        best_kl = None
        for c in candidates:
            trial = tuple(sorted(schedule + [c]))
            kl = kl_divergence(baseline, eval_fn(trial))
            if best_kl is None or kl < best_kl:
                best, best_kl = c, kl
        if best_kl is None or best_kl > kl_budget:
            break
        schedule.append(best)
        candidates.remove(best)
    return tuple(sorted(schedule))
