"""Scheduling: continuous-batching request admission + refresh/reuse
schedule calibration.

Part 1 — continuous batching (serving side). `RequestQueue` is a FIFO of
`Request`s with arrival times measured on the serving loop's virtual clock
(fused-step index); `Scheduler` owns a fixed set of engine batch slots and
tracks each through free → prefilling → decoding → finished → free. The
engine asks the scheduler which arrived requests fit into freed slots
(`admit`), marks them decoding once their per-slot re-prefill has landed in
the batch cache, and hands slots back on completion (`finish`/`release`).
The scheduler never touches device state — it is pure bookkeeping, so its
invariants (no double assignment, FIFO fairness, freed-slot reuse, queue
drains) are testable without a model (tests/test_schedule_admission.py).

Part 2 — refresh/reuse schedule calibration: training-free IndexCache-style
greedy search (paper §5.2, Table 1 footnote). Given a target model and a
calibration batch, greedily grow the set of REUSE layers: at each round,
tentatively add each remaining candidate layer and measure the output-logit
KL divergence against the all-refresh baseline on a verification workload;
keep the candidate with the smallest KL as long as it stays under
``kl_budget``. Layer 0 is never a candidate (mandatory refresh).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


# ------------------------------------------------------ continuous batching
class SlotState(enum.Enum):
    FREE = "free"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One queued generation request. ``arrival`` / ``admitted_at`` /
    ``finished_at`` are virtual-clock times (fused-step indices), so queue
    delays are deterministic and testable without wall-clock noise."""
    req_id: int
    prompt: np.ndarray
    max_new_tokens: int = 0          # 0 = serve config default
    arrival: float = 0.0
    admitted_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def queue_delay(self) -> Optional[float]:
        if self.admitted_at is None:
            return None
        return self.admitted_at - self.arrival


class RequestQueue:
    """FIFO over arrived requests: pop order is (arrival, submission order) —
    submission order is the list order, kept stable by pop_arrived's strict
    ``<`` comparison."""

    def __init__(self):
        self._items: List[Request] = []

    def submit(self, req: Request) -> None:
        self._items.append(req)

    def __len__(self) -> int:
        return len(self._items)

    def pop_arrived(self, now: float) -> Optional[Request]:
        """Earliest-arrival request with arrival <= now (stable on ties)."""
        best_i = self._best_arrived(now)
        return self._items.pop(best_i) if best_i is not None else None

    def peek_arrived(self, now: float) -> Optional[Request]:
        """Like pop_arrived but non-destructive — admission gates (free
        slots AND free pages) inspect the head before committing to it."""
        best_i = self._best_arrived(now)
        return self._items[best_i] if best_i is not None else None

    def peek_arrived_where(self, now: float, pred) -> Optional[Request]:
        """Earliest arrived request satisfying ``pred`` (stable on ties), or
        None — the bucket-aware admission policy's preference probe."""
        best_i = self._best_arrived(now, pred)
        return self._items[best_i] if best_i is not None else None

    def remove(self, req: Request) -> None:
        """Identity-based removal: dataclass __eq__ would compare the
        ndarray prompt field (ambiguous truth value)."""
        for i, r in enumerate(self._items):
            if r is req:
                self._items.pop(i)
                return
        raise ValueError(f"request {req.req_id} is not in the queue")

    def _best_arrived(self, now: float, pred=None) -> Optional[int]:
        best_i = None
        for i, r in enumerate(self._items):
            if r.arrival <= now and (pred is None or pred(r)) and \
                    (best_i is None
                     or r.arrival < self._items[best_i].arrival):
                best_i = i
        return best_i

    def next_arrival(self) -> Optional[float]:
        return min((r.arrival for r in self._items), default=None)


class Scheduler:
    """Slot bookkeeping for mid-flight admission into a fixed batch.

    Lifecycle per slot: FREE --admit--> PREFILLING --mark_decoding-->
    DECODING --finish--> FINISHED --release--> FREE. Transition methods
    raise on invalid moves so engine bugs surface as errors, not silent
    double-assignments.

    Paged-KV gating: when ``pages_for`` / ``free_pages`` are supplied (the
    engine's page accounting), admission requires BOTH a free slot and
    enough free pages for the request's whole reservation. The FIFO head
    blocks admission while it does not fit (no overtaking — pages free as
    decoding rows finish, so head-of-line waits resolve; a request larger
    than the entire pool is rejected by the engine at submit time, which is
    what keeps the wait from becoming a deadlock). ``page_occupancy()``
    reports the allocated-page fraction for serving stats.

    Bucket-aware admission (``policy="bucket"``, needs ``bucket_of``): when
    filling a freed slot, prefer the earliest arrived request whose context
    bucket already has live rows in the batch — keeping execution groups
    homogeneous so the bucketed serving loop launches fewer, fuller groups.
    Falls back to the plain FIFO head when no arrived request matches (a new
    bucket is opened rather than starving it). The default policy stays
    plain FIFO; page gating applies to whichever candidate the policy picks.
    """

    def __init__(self, num_slots: int,
                 pages_for: Optional[Callable[[Request], int]] = None,
                 free_pages: Optional[Callable[[], int]] = None,
                 total_pages: Optional[int] = None,
                 bucket_of: Optional[Callable[[Request], int]] = None,
                 policy: str = "fifo"):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        if (pages_for is None) != (free_pages is None):
            raise ValueError("pages_for and free_pages come as a pair")
        if policy not in ("fifo", "bucket"):
            raise ValueError(f"unknown admission policy {policy!r}; "
                             "choose fifo or bucket")
        if policy == "bucket" and bucket_of is None:
            raise ValueError("policy='bucket' needs bucket_of to classify "
                             "requests into context buckets")
        self.num_slots = num_slots
        self.pages_for = pages_for
        self.free_pages = free_pages
        self.total_pages = total_pages
        self.bucket_of = bucket_of
        self.policy = policy
        self.queue = RequestQueue()
        self.states: List[SlotState] = [SlotState.FREE] * num_slots
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.completed: List[Request] = []

    # ------------------------------------------------------------ queue side
    def submit(self, req: Request) -> None:
        self.queue.submit(req)

    # ------------------------------------------------------------ admission
    def admit(self, now: float) -> List[Tuple[int, Request]]:
        """Assign arrived queued requests to FREE slots (FIFO), marking each
        slot PREFILLING. With page gating, a request is only placed while
        its page reservation fits the pool's free-page headroom (pages
        claimed by requests placed earlier in this same call are counted);
        otherwise the queue stays pending. Returns the (slot, request)
        assignments made."""
        placed: List[Tuple[int, Request]] = []
        reserved = 0
        for slot in range(self.num_slots):
            if self.states[slot] is not SlotState.FREE:
                continue
            req = self._pick_candidate(now)
            if req is None:
                break
            if self.pages_for is not None:
                need = self.pages_for(req)
                if need > self.free_pages() - reserved:
                    break            # head-of-line wait for pages, FIFO-fair
                reserved += need
            self.queue.remove(req)
            if self.slot_req[slot] is not None:
                raise RuntimeError(f"slot {slot} is FREE but still holds "
                                   f"request {self.slot_req[slot].req_id}")
            req.admitted_at = now
            self.states[slot] = SlotState.PREFILLING
            self.slot_req[slot] = req
            placed.append((slot, req))
        return placed

    def _pick_candidate(self, now: float) -> Optional[Request]:
        """The next request the admission policy would place: FIFO head, or —
        under the bucket policy — the earliest arrival whose bucket already
        has live rows (falling back to the FIFO head when none matches, so
        empty batches and fresh buckets still admit)."""
        if self.policy == "bucket":
            live = {self.bucket_of(r) for r in self.slot_req if r is not None}
            if live:
                req = self.queue.peek_arrived_where(
                    now, lambda r: self.bucket_of(r) in live)
                if req is not None:
                    return req
        return self.queue.peek_arrived(now)

    def mark_decoding(self, slot: int) -> None:
        if self.states[slot] is not SlotState.PREFILLING:
            raise RuntimeError(f"slot {slot} is {self.states[slot].value}, "
                               "expected prefilling")
        self.states[slot] = SlotState.DECODING

    def finish(self, slot: int, now: float) -> Request:
        if self.states[slot] is not SlotState.DECODING:
            raise RuntimeError(f"slot {slot} is {self.states[slot].value}, "
                               "expected decoding")
        req = self.slot_req[slot]
        req.finished_at = now
        self.states[slot] = SlotState.FINISHED
        self.completed.append(req)
        return req

    def release(self, slot: int) -> None:
        if self.states[slot] is not SlotState.FINISHED:
            raise RuntimeError(f"slot {slot} is {self.states[slot].value}, "
                               "expected finished")
        self.states[slot] = SlotState.FREE
        self.slot_req[slot] = None

    # ------------------------------------------------------------ queries
    def request_at(self, slot: int) -> Optional[Request]:
        return self.slot_req[slot]

    def decoding_mask(self) -> np.ndarray:
        return np.array([s is SlotState.DECODING for s in self.states], bool)

    def occupancy(self) -> float:
        busy = sum(s is not SlotState.FREE for s in self.states)
        return busy / self.num_slots

    def page_occupancy(self) -> float:
        """Allocated fraction of the KV page pool (0.0 when not page-gated)."""
        if self.free_pages is None or not self.total_pages:
            return 0.0
        return 1.0 - self.free_pages() / self.total_pages

    def bucket_occupancy(self) -> dict:
        """Decoding-slot fraction per context bucket (empty without a
        ``bucket_of`` classifier) — the per-bucket serving stat the bucketed
        engine reports next to plain slot occupancy."""
        if self.bucket_of is None:
            return {}
        occ: dict = {}
        for state, req in zip(self.states, self.slot_req):
            if state is SlotState.DECODING and req is not None:
                b = int(self.bucket_of(req))
                occ[b] = occ.get(b, 0.0) + 1.0 / self.num_slots
        return occ

    def next_arrival(self) -> Optional[float]:
        return self.queue.next_arrival()

    def idle(self) -> bool:
        return len(self.queue) == 0 and all(
            s is SlotState.FREE for s in self.states)


def poisson_arrivals(n: int, rate_per_step: float,
                     seed: int = 0) -> np.ndarray:
    """Deterministic Poisson-process arrival replay: n arrival times on the
    virtual step clock with exponential inter-arrival gaps of mean
    1/rate_per_step. rate <= 0 means everything arrives at t=0."""
    if rate_per_step <= 0:
        return np.zeros((n,), np.float64)
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_per_step, size=n))


def kl_divergence(p_logits: np.ndarray, q_logits: np.ndarray) -> float:
    """Mean KL(p || q) over leading dims; logits (..., V)."""
    p_logits = p_logits.astype(np.float64)
    q_logits = q_logits.astype(np.float64)
    p_logits = p_logits - p_logits.max(-1, keepdims=True)
    q_logits = q_logits - q_logits.max(-1, keepdims=True)
    lp = p_logits - np.log(np.exp(p_logits).sum(-1, keepdims=True))
    lq = q_logits - np.log(np.exp(q_logits).sum(-1, keepdims=True))
    p = np.exp(lp)
    return float((p * (lp - lq)).sum(-1).mean())


def greedy_calibrate(eval_fn: Callable[[Tuple[int, ...]], np.ndarray],
                     num_layers: int, kl_budget: float = 0.02,
                     max_reuse: Optional[int] = None) -> Tuple[int, ...]:
    """eval_fn(schedule) -> verification logits for the calibration batch.

    Returns the calibrated REUSE-layer index tuple (sorted)."""
    baseline = eval_fn(())
    schedule: List[int] = []
    candidates = list(range(1, num_layers))
    max_reuse = max_reuse if max_reuse is not None else num_layers - 1
    while candidates and len(schedule) < max_reuse:
        best = None
        best_kl = None
        for c in candidates:
            trial = tuple(sorted(schedule + [c]))
            kl = kl_divergence(baseline, eval_fn(trial))
            if best_kl is None or kl < best_kl:
                best, best_kl = c, kl
        if best_kl is None or best_kl > kl_budget:
            break
        schedule.append(best)
        candidates.remove(best)
    return tuple(sorted(schedule))
