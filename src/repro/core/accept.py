"""Speculative accept/reject over draft trees.

Two rules, both host-side (gamma <= 128 — the per-step cost is negligible and
keeping the dynamic control flow off-device mirrors production engines):

* greedy (temperature 0): walk from the root; a child is accepted iff its
  token equals the target argmax at its parent's context. The bonus token is
  the target argmax at the deepest accepted node.

* stochastic (SpecInfer/EAGLE multi-round rejection sampling): preserves the
  target distribution exactly for any draft distribution q — children are
  tried in order; child c with token t is accepted w.p. min(1, p(t)/q(t));
  on rejection p <- normalize(max(p - q, 0)). If all children are rejected,
  the bonus is sampled from the residual.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.tree import TreeTopology


@dataclasses.dataclass
class AcceptResult:
    path: np.ndarray        # (n_accepted + 1,) node indices incl. root, root-to-leaf
    tokens: np.ndarray      # (n_accepted + 1,) accepted draft tokens + bonus token
    bonus: int
    n_accepted: int         # accepted DRAFT nodes (path length minus the root)


def children_lists(topo: TreeTopology) -> List[List[int]]:
    ch: List[List[int]] = [[] for _ in range(topo.num_nodes + 1)]
    for i, p in enumerate(topo.parents):
        ch[p + 1].append(i)
    return ch


def greedy_tree_accept(topo: TreeTopology, draft_tokens: np.ndarray,
                       verify_logits: np.ndarray) -> AcceptResult:
    """draft_tokens: (T,) node tokens (node 0 = pending root, always
    accepted); verify_logits: (T, V) target logits at each node. The walk
    starts at the root using its own verify logits — the target's prediction
    after processing the pending token."""
    ch = children_lists(topo)
    cur = 0
    logits = verify_logits[0]
    path: List[int] = [0]
    toks: List[int] = []
    while True:
        best = int(np.argmax(logits))
        nxt = None
        for c in ch[cur + 1]:
            if int(draft_tokens[c]) == best:
                nxt = c
                break
        if nxt is None:
            break
        path.append(nxt)
        toks.append(int(draft_tokens[nxt]))
        logits = verify_logits[nxt]
        cur = nxt
    bonus = int(np.argmax(logits))
    return AcceptResult(path=np.array(path, np.int64),
                        tokens=np.array(toks + [bonus], np.int64),
                        bonus=bonus, n_accepted=len(path) - 1)


def _softmax(x: np.ndarray, temperature: float = 1.0) -> np.ndarray:
    x = x.astype(np.float64) / max(temperature, 1e-6)
    x = x - x.max()
    e = np.exp(x)
    return e / e.sum()


def stochastic_tree_accept(topo: TreeTopology, draft_tokens: np.ndarray,
                           verify_logits: np.ndarray, node_q: np.ndarray,
                           rng: np.random.Generator,
                           temperature: float = 1.0) -> AcceptResult:
    """SpecInfer-style multi-round rejection sampling over a rooted tree.

    node_q: (T, V) draft distribution *at* each node (the distribution its
    children were drawn from). Output tokens are distributed exactly as the
    target model's.
    """
    ch = children_lists(topo)
    cur = 0
    p = _softmax(verify_logits[0], temperature)
    q = node_q[0]
    path: List[int] = [0]
    toks: List[int] = []
    while True:
        accepted = None
        p_res = p.copy()
        for c in ch[cur + 1]:
            t = int(draft_tokens[c])
            qt = max(float(q[t]), 1e-12)
            if rng.uniform() < min(1.0, float(p_res[t]) / qt):
                accepted = c
                break
            p_res = np.maximum(p_res - q, 0.0)
            s = p_res.sum()
            p_res = p_res / s if s > 0 else np.full_like(p_res, 1.0 / len(p_res))
        if accepted is None:
            bonus = int(rng.choice(len(p_res), p=p_res / p_res.sum()))
            return AcceptResult(path=np.array(path, np.int64),
                                tokens=np.array(toks + [bonus], np.int64),
                                bonus=bonus, n_accepted=len(path) - 1)
        path.append(accepted)
        toks.append(int(draft_tokens[accepted]))
        p = _softmax(verify_logits[accepted], temperature)
        q = node_q[accepted]
        cur = accepted
        if not ch[cur + 1]:
            bonus = int(rng.choice(len(p), p=p))
            return AcceptResult(path=np.array(path, np.int64),
                                tokens=np.array(toks + [bonus], np.int64),
                                bonus=bonus, n_accepted=len(path) - 1)


def pad_path(path: np.ndarray, pad_to: int) -> np.ndarray:
    """Pad a root-to-leaf accepted path (root included, so len >= 1) to a
    static length for jitted commit: padding repeats the last entry."""
    out = np.zeros((pad_to,), np.int64)
    k = min(len(path), pad_to)
    out[:k] = path[:k]
    out[k:] = path[k - 1]
    return out
