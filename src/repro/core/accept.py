"""Speculative accept/reject over draft trees.

Two rules, each with a host (numpy) and a device (pure ``jnp``) form:

* greedy (temperature 0): walk from the root; a child is accepted iff its
  token equals the target argmax at its parent's context. The bonus token is
  the target argmax at the deepest accepted node.

* stochastic (SpecInfer/EAGLE multi-round rejection sampling): preserves the
  target distribution exactly for any draft distribution q — children are
  tried in order; child c with token t is accepted w.p. min(1, p(t)/q(t));
  on rejection p <- normalize(max(p - q, 0)). If all children are rejected,
  the bonus is sampled from the residual.

The device forms (`greedy_tree_accept_device`, `stochastic_tree_accept_device`)
run the walk as a fixed-length `lax.scan` over the static children matrix, so
they fuse into the jitted verification step and only a handful of ints ever
cross to the host. Randomness is injected as explicit uniform arrays with a
fixed consumption layout (`accept_u[round, child_rank]`, one `bonus_u`), and
the host forms consume the same layout — host and device are bit-compatible
given the same uniforms (see tests/test_accept_device.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import TreeTopology, children_matrix


@dataclasses.dataclass
class AcceptResult:
    path: np.ndarray        # (n_accepted + 1,) node indices incl. root, root-to-leaf
    tokens: np.ndarray      # (n_accepted + 1,) accepted draft tokens + bonus token
    bonus: int
    n_accepted: int         # accepted DRAFT nodes (path length minus the root)


def children_lists(topo: TreeTopology) -> List[List[int]]:
    ch: List[List[int]] = [[] for _ in range(topo.num_nodes + 1)]
    for i, p in enumerate(topo.parents):
        ch[p + 1].append(i)
    return ch


def greedy_tree_accept(topo: TreeTopology, draft_tokens: np.ndarray,
                       verify_logits: np.ndarray) -> AcceptResult:
    """draft_tokens: (T,) node tokens (node 0 = pending root, always
    accepted); verify_logits: (T, V) target logits at each node. The walk
    starts at the root using its own verify logits — the target's prediction
    after processing the pending token."""
    ch = children_lists(topo)
    cur = 0
    logits = verify_logits[0]
    path: List[int] = [0]
    toks: List[int] = []
    while True:
        best = int(np.argmax(logits))
        nxt = None
        for c in ch[cur + 1]:
            if int(draft_tokens[c]) == best:
                nxt = c
                break
        if nxt is None:
            break
        path.append(nxt)
        toks.append(int(draft_tokens[nxt]))
        logits = verify_logits[nxt]
        cur = nxt
    bonus = int(np.argmax(logits))
    return AcceptResult(path=np.array(path, np.int64),
                        tokens=np.array(toks + [bonus], np.int64),
                        bonus=bonus, n_accepted=len(path) - 1)


def _softmax(x: np.ndarray, temperature: float = 1.0) -> np.ndarray:
    # float32 to match the on-device form bit-for-bit (x64 is disabled there)
    x = x.astype(np.float32) / np.float32(max(temperature, 1e-6))
    x = x - x.max()
    e = np.exp(x)
    return e / e.sum()


def _inverse_cdf(p: np.ndarray, u: float) -> int:
    cdf = np.cumsum(p / max(p.sum(), 1e-30))
    return int(min(np.searchsorted(cdf, u), len(p) - 1))


def stochastic_tree_accept_uniforms(topo: TreeTopology, draft_tokens: np.ndarray,
                                    verify_logits: np.ndarray, node_q: np.ndarray,
                                    accept_u: np.ndarray, bonus_u: float,
                                    temperature: float = 1.0) -> AcceptResult:
    """SpecInfer-style multi-round rejection sampling over a rooted tree,
    driven by an explicit uniform stream.

    node_q: (T, V) draft distribution *at* each node (the distribution its
    children were drawn from). accept_u: (max_depth + 1, k_max) uniforms —
    round r's j-th child consumes accept_u[r, j]; bonus_u drives the single
    inverse-CDF bonus draw. Output tokens are distributed exactly as the
    target model's.
    """
    maxd = int(topo.depths.max()) if topo.num_nodes else 0
    if accept_u.shape[0] < maxd + 1:
        raise ValueError(f"accept_u needs {maxd + 1} rounds (tree depth {maxd} "
                         f"+ terminal), got {accept_u.shape[0]}")
    ch = children_lists(topo)
    cur = 0
    path: List[int] = [0]
    toks: List[int] = []
    for r in range(accept_u.shape[0]):
        p = _softmax(verify_logits[cur], temperature)
        q = node_q[cur].astype(np.float32)
        accepted: Optional[int] = None
        p_res = p.copy()
        for j, c in enumerate(ch[cur + 1]):
            t = int(draft_tokens[c])
            qt = max(float(q[t]), 1e-12)
            if accept_u[r, j] < min(1.0, float(p_res[t]) / qt):
                accepted = c
                break
            p_res = np.maximum(p_res - q, 0.0)
            s = p_res.sum()
            p_res = p_res / s if s > 0 else np.full_like(p_res, 1.0 / len(p_res))
        if accepted is None:
            # covers both full rejection and leaf exhaustion (no children:
            # p_res == p untouched, so the bonus is drawn from p itself)
            bonus = _inverse_cdf(p_res, bonus_u)
            return AcceptResult(path=np.array(path, np.int64),
                                tokens=np.array(toks + [bonus], np.int64),
                                bonus=bonus, n_accepted=len(path) - 1)
        path.append(accepted)
        toks.append(int(draft_tokens[accepted]))
        cur = accepted
    # a walk that accepts at every level reaches a leaf by round maxd, and a
    # leaf round always terminates via the accepted-is-None branch above
    raise AssertionError("unreachable: the final round terminates at a leaf")


def draw_uniforms(topo: TreeTopology, rng: np.random.Generator):
    """The (accept_u, bonus_u) layout both accept forms consume: one row per
    walk round (max_depth + 1: the last round can only terminate), one column
    per child rank."""
    maxd = int(topo.depths.max()) if topo.num_nodes else 0
    kmax = max(1, children_matrix(topo).shape[1])
    return rng.uniform(size=(maxd + 1, kmax)), float(rng.uniform())


def stochastic_tree_accept(topo: TreeTopology, draft_tokens: np.ndarray,
                           verify_logits: np.ndarray, node_q: np.ndarray,
                           rng: np.random.Generator,
                           temperature: float = 1.0) -> AcceptResult:
    """Rejection sampling with uniforms drawn from ``rng`` (host entry point)."""
    accept_u, bonus_u = draw_uniforms(topo, rng)
    return stochastic_tree_accept_uniforms(topo, draft_tokens, verify_logits,
                                           node_q, accept_u, bonus_u, temperature)


# ------------------------------------------------------------------ device
def greedy_tree_accept_device(child_mat, max_depth: int, draft_tokens,
                              verify_logits):
    """Pure-jnp greedy tree accept — fuses into the jitted verify step.

    child_mat: (T, k_max) int32 children of each node in sibling order (-1
    padded; static per topology); draft_tokens (T,); verify_logits (T, V).
    Returns (path (max_depth+1,), tokens (max_depth+1,), bonus, n_accepted) —
    path/tokens padded by repeating the last entry / the bonus, exactly the
    `pad_path` layout the jitted commit consumes. Matches the host walk
    (first matching child wins) node-for-node.
    """
    draft_tokens = jnp.asarray(draft_tokens)
    argm = jnp.argmax(jnp.asarray(verify_logits), axis=-1).astype(jnp.int32)  # (T,)
    child_mat = jnp.asarray(child_mat, jnp.int32)

    def body(carry, _):
        cur, alive, n_acc = carry
        kids = child_mat[cur]                                     # (k_max,)
        toks = draft_tokens[jnp.clip(kids, 0)]
        match = (toks == argm[cur]) & (kids >= 0)
        found = match.any() & alive
        nxt = jnp.where(found, kids[jnp.argmax(match)], cur)
        return (nxt, found, n_acc + found.astype(jnp.int32)), nxt

    init = (jnp.int32(0), jnp.bool_(True), jnp.int32(0))
    (cur, _, n_acc), tail = jax.lax.scan(body, init, None, length=max_depth)
    path = jnp.concatenate([jnp.zeros((1,), jnp.int32), tail])
    bonus = argm[cur]
    toks_path = draft_tokens[path[1:]].astype(jnp.int32)
    tokens = jnp.where(jnp.arange(max_depth) < n_acc, toks_path, bonus)
    tokens = jnp.concatenate([tokens, bonus[None]])
    return path, tokens, bonus, n_acc


def stochastic_tree_accept_device(child_mat, max_depth: int, draft_tokens,
                                  verify_logits, node_q, accept_u, bonus_u,
                                  temperature: float = 1.0):
    """Pure-jnp multi-round rejection sampling; same uniform-consumption
    layout as `stochastic_tree_accept_uniforms` (accept_u (max_depth+1, k_max),
    scalar bonus_u), so host and device walks agree draw-for-draw.

    Returns (path (max_depth+1,), tokens (max_depth+1,), bonus, n_accepted).
    """
    T, kmax = child_mat.shape
    V = verify_logits.shape[-1]
    child_mat = jnp.asarray(child_mat, jnp.int32)
    draft_tokens = jnp.asarray(draft_tokens)
    accept_u = jnp.asarray(accept_u, jnp.float32)
    p_all = jax.nn.softmax(
        jnp.asarray(verify_logits).astype(jnp.float32) / max(temperature, 1e-6),
        axis=-1)
    q_all = jnp.asarray(node_q).astype(jnp.float32)

    def round_body(carry, r):
        cur, alive, n_acc, bonus, have_bonus = carry
        p, q = p_all[cur], q_all[cur]
        kids = child_mat[cur]

        def child_body(c, j):
            p_res, acc_node, accepted = c
            kid = kids[j]
            valid = (kid >= 0) & (~accepted)
            t = draft_tokens[jnp.clip(kid, 0)]
            ratio = p_res[t] / jnp.maximum(q[t], 1e-12)
            ok = valid & (accept_u[r, j] < jnp.minimum(1.0, ratio))
            rejected = valid & (~ok)
            res = jnp.maximum(p_res - q, 0.0)
            s = res.sum()
            res = jnp.where(s > 0, res / s, jnp.full_like(res, 1.0 / V))
            return (jnp.where(rejected, res, p_res),
                    jnp.where(ok, kid, acc_node), accepted | ok), None

        (p_res, acc_node, accepted), _ = jax.lax.scan(
            child_body, (p, jnp.int32(0), jnp.bool_(False)), jnp.arange(kmax))
        found = accepted & alive
        terminate = alive & (~accepted)
        cdf = jnp.cumsum(p_res / jnp.maximum(p_res.sum(), 1e-30))
        draw = jnp.clip(jnp.searchsorted(cdf, bonus_u), 0, V - 1).astype(jnp.int32)
        bonus = jnp.where(terminate & (~have_bonus), draw, bonus)
        nxt = jnp.where(found, acc_node, cur)
        return (nxt, found, n_acc + found.astype(jnp.int32), bonus,
                have_bonus | terminate), nxt

    init = (jnp.int32(0), jnp.bool_(True), jnp.int32(0), jnp.int32(0),
            jnp.bool_(False))
    (cur, _, n_acc, bonus, _), tail = jax.lax.scan(
        round_body, init, jnp.arange(max_depth + 1))
    path = jnp.concatenate([jnp.zeros((1,), jnp.int32), tail[:max_depth]])
    toks_path = draft_tokens[path[1:]].astype(jnp.int32)
    tokens = jnp.where(jnp.arange(max_depth) < n_acc, toks_path, bonus)
    tokens = jnp.concatenate([tokens, bonus[None]])
    return path, tokens, bonus, n_acc


def pad_path(path: np.ndarray, pad_to: int) -> np.ndarray:
    """Pad a root-to-leaf accepted path (root included, so len >= 1) to a
    static length for jitted commit: padding repeats the last entry."""
    out = np.zeros((pad_to,), np.int64)
    k = min(len(path), pad_to)
    out[:k] = path[:k]
    out[k:] = path[k - 1]
    return out
