"""Cross-query overlap machinery (paper §4).

Three pieces:
  * overlap statistics — the Fig. 2 / Fig. 4 profiling quantities;
  * merged schedule (exact variant) — per-group union + dedup of selected
    block indices with per-query ownership masks;
  * shared index (approximate variant) — the representative query's indices
    broadcast to its whole group.

All functions are shape-static and jit-safe: merged schedules are padded to
the group capacity C * n with a sentinel, exactly what the Pallas kernel's
scalar-prefetch path consumes.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

SENTINEL = jnp.int32(2 ** 30)


def pad_to_groups(T: int, C: int) -> int:
    return -(-T // C)


def _dedupe(idx, valid):
    """Sort and keep only first occurrences (set semantics for ratio math)."""
    key = jnp.where(valid, idx, SENTINEL)
    s = jnp.sort(key, axis=-1)
    first = jnp.concatenate([jnp.ones(s.shape[:-1] + (1,), bool),
                             s[..., 1:] != s[..., :-1]], axis=-1)
    v = first & (s < SENTINEL)
    return s, v


def overlap_ratio(idx_a, valid_a, idx_b, valid_b):
    """|I_a ∩ I_b| / |I_a ∪ I_b| (set semantics) for two index sets (..., n)."""
    ia, va = _dedupe(idx_a, valid_a)
    ib, vb = _dedupe(idx_b, valid_b)
    eq = (ia[..., :, None] == ib[..., None, :]) & \
        va[..., :, None] & vb[..., None, :]
    inter = eq.any(-1).sum(-1).astype(jnp.float32)
    na = va.sum(-1).astype(jnp.float32)
    nb = vb.sum(-1).astype(jnp.float32)
    union = na + nb - inter
    return jnp.where(union > 0, inter / union, 1.0)


def adjacent_overlap(sel_idx, sel_valid):
    """Mean selected-block overlap between adjacent verifier queries
    (Fig. 2). sel_idx: (B, T, Hkv, n). Returns (T-1,) per-adjacency means."""
    a, b = sel_idx[:, :-1], sel_idx[:, 1:]
    va, vb = sel_valid[:, :-1], sel_valid[:, 1:]
    r = overlap_ratio(a, va, b, vb)          # (B, T-1, Hkv)
    return r.mean(axis=(0, 2))


def pairwise_overlap_by_distance(sel_idx, sel_valid, positions, max_delta: int = 16):
    """Fig. 4: overlap ratio vs |token-position distance|. Returns
    (deltas (max_delta,), mean overlap (max_delta,))."""
    B, T, H, n = sel_idx.shape
    r = overlap_ratio(sel_idx[:, :, None], sel_valid[:, :, None],
                      sel_idx[:, None, :], sel_valid[:, None, :])   # (B,T,T,H)
    d = jnp.abs(positions[:, :, None] - positions[:, None, :])      # (B,T,T)
    out = []
    for delta in range(1, max_delta + 1):
        m = jnp.broadcast_to((d == delta)[..., None], r.shape)
        tot = jnp.where(m, r, 0.0).sum()
        cnt = m.sum()
        out.append(jnp.where(cnt > 0, tot / cnt, jnp.nan))
    return np.arange(1, max_delta + 1), jnp.stack(out)


@functools.lru_cache(maxsize=4096)
def group_queries(T: int, C: int):
    """Static grouping of a flattened draft batch into ceil(T/C) groups of up
    to C adjacent queries (the traversal order determines adjacency).

    Memoized by (T, C): the layout map is pure host-side numpy and was being
    rebuilt on every fused-verify call (`kernels/nsa_verify/ops.prepare_groups`
    invokes it once per layer per step). The cached array is marked
    read-only so call sites cannot mutate the shared copy."""
    ngroups = pad_to_groups(T, C)
    pad = ngroups * C - T
    qidx = np.concatenate([np.arange(T), np.full(pad, T - 1)])      # clamp pad
    qmap = qidx.reshape(ngroups, C)
    qmap.setflags(write=False)
    return qmap, pad


def merged_schedule(sel_idx, sel_valid, C: int):
    """Exact merged-schedule (paper §4.2): per group, the sorted union of the
    member queries' selected blocks, deduplicated, plus ownership masks.

    sel_idx/sel_valid: (B, T, Hkv, n)  ->
      merged:    (B, G, Hkv, C*n) int32, sorted, padded with SENTINEL
      own:       (B, G, Hkv, C, C*n) bool — query c owns merged slot s
      m_valid:   (B, G, Hkv, C*n) bool
    Loading each merged slot once and masking rows by ``own`` is semantically
    identical to independent per-query execution.
    """
    B, T, H, n = sel_idx.shape
    qmap, pad = group_queries(T, C)
    G = qmap.shape[0]
    gi = jnp.asarray(qmap)                                           # (G, C)
    idx = sel_idx[:, gi]                                             # (B,G,C,H,n)
    val = sel_valid[:, gi]
    if pad:
        # padded replicas must not contribute ownership
        padmask = jnp.asarray(np.arange(G * C).reshape(G, C) < T)
        val = val & padmask[None, :, :, None, None]
    idx = jnp.where(val, idx, SENTINEL)
    flat = idx.transpose(0, 1, 3, 2, 4).reshape(B, G, H, C * n)      # (B,G,H,C*n)
    fval = val.transpose(0, 1, 3, 2, 4).reshape(B, G, H, C * n)
    merged = jnp.sort(flat, axis=-1)
    # dedup: first occurrence survives
    first = jnp.concatenate([
        jnp.ones(merged.shape[:-1] + (1,), bool),
        merged[..., 1:] != merged[..., :-1]], axis=-1)
    m_valid = first & (merged < SENTINEL)
    merged = jnp.where(m_valid, merged, SENTINEL)
    # compact valid entries to the front (stable: sort by (invalid, value))
    key = jnp.where(m_valid, merged, SENTINEL)
    order = jnp.argsort(key, axis=-1)
    merged = jnp.take_along_axis(merged, order, axis=-1)
    m_valid = jnp.take_along_axis(m_valid, order, axis=-1)
    # ownership: query c owns slot s iff merged[s] in its original set
    own = _ownership(merged, idx, val)
    return merged, own, m_valid


def _ownership(merged, idx, val):
    """merged: (B,G,H,M); idx/val: (B,G,C,H,n) -> own (B,G,H,C,M)."""
    cand = jnp.where(val, idx, -1).transpose(0, 1, 3, 2, 4)          # (B,G,H,C,n)
    eq = merged[:, :, :, None, :, None] == cand[:, :, :, :, None, :]  # (B,G,H,C,M,n)
    return eq.any(-1)                                                # (B,G,H,C,M)


def shared_index(sel_idx, sel_valid, positions, C: int):
    """Approximate shared-index variant (paper §4.3): every query in a group
    adopts the representative's selected blocks. Representative = the member
    with the longest prefix (max position), per the paper.

    Returns (idx, valid) with the same (B, T, Hkv, n) shape so downstream
    verification is oblivious to the grouping mode.
    """
    B, T, H, n = sel_idx.shape
    qmap, pad = group_queries(T, C)
    G = qmap.shape[0]
    gi = jnp.asarray(qmap)                                           # (G, C)
    gpos = positions[:, gi]                                          # (B, G, C)
    rep_c = jnp.argmax(gpos, axis=-1)                                # (B, G)
    rep_q = jnp.take_along_axis(jnp.broadcast_to(gi[None], (B, G, gi.shape[1])),
                                rep_c[..., None], axis=-1)[..., 0]   # (B, G)
    rep_idx = jnp.take_along_axis(sel_idx, rep_q[:, :, None, None].repeat(H, 2).repeat(n, 3), axis=1)
    rep_val = jnp.take_along_axis(sel_valid, rep_q[:, :, None, None].repeat(H, 2).repeat(n, 3), axis=1)
    # broadcast back to every member of the group
    out_idx = jnp.repeat(rep_idx, C, axis=1)[:, :T]
    out_val = jnp.repeat(rep_val, C, axis=1)[:, :T]
    # exact per-query causality is enforced downstream by position masks, but
    # a representative deeper than the member may select the block containing
    # positions the member cannot see — masked inside attention (tok <= pos).
    return out_idx, out_val
