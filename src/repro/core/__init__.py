"""SSV core: the paper's primary contribution.

tree     — rooted draft-tree topologies, BFS/DFS flattening, tree masks
draft    — draft model config + tree expansion
accept   — greedy + stochastic (SpecInfer-style) tree acceptance
overlap  — cross-query overlap stats, merged-schedule / shared-index builders
engine   — the draft -> sparse-verify -> accept serving loop
kvstore  — KV-cache store: dense + paged (page-table) backends, page allocator
planner  — profile-guided prompt-adaptive orchestration (Algorithm 1)
schedule — continuous-batching request queue/slot scheduler + IndexCache-style
           refresh/reuse greedy calibration
"""
from repro.core import accept, draft, engine, kvstore, overlap, planner, schedule, tree  # noqa: F401
