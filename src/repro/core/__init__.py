"""SSV core: the paper's primary contribution.

tree     — rooted draft-tree topologies, BFS/DFS flattening, tree masks
draft    — draft model config + tree expansion
accept   — greedy + stochastic (SpecInfer-style) tree acceptance
overlap  — cross-query overlap stats, merged-schedule / shared-index builders
engine   — the draft -> sparse-verify -> accept serving loop
planner  — profile-guided prompt-adaptive orchestration (Algorithm 1)
schedule — continuous-batching request queue/slot scheduler + IndexCache-style
           refresh/reuse greedy calibration
"""
from repro.core import accept, draft, engine, overlap, planner, schedule, tree  # noqa: F401
