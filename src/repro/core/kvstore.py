"""KVCacheStore — the KV storage subsystem behind every serving cache.

Two backends hide behind one interface:

  dense — the seed layout: per-request ``(B, max_context, Hkv, Dh)`` K/V
      buffers, written with ``dynamic_update_slice``. Byte-identical to the
      pre-store code paths (token-equality is tested, not assumed).

  paged — a physical page pool ``(num_pages, page_size, Hkv, Dh)`` shared by
      every request plus a per-row page table ``(B, max_pages)`` mapping
      logical page -> physical page (-1 = unmapped). Admission allocates a
      request's pages from a host-side free list (`PageAllocator`), commits
      scatter accepted tokens into the row's own pages (donated, in place),
      and completion returns the pages to the pool — so batch KV memory
      scales with live tokens, not ``batch * max_context``.

The page size is aligned with the NSA selection-block granularity
(``page_size % sel_block == 0``, default ``page_size == sel_block``): a
selected block index resolves to a page-table entry, turning the paper's
sparse selected-KV gather into natively paged access. Out-of-range or
unmapped lookups read an explicit zero page (never a silently clamped
neighbor) and writes to them are dropped — the adversarial-index contract
``tests/test_kvstore.py`` pins down.

Device-side state is a plain pytree (`KVView` wraps the per-layer K/V
storage plus the shared page table); host-side page accounting is the
`PageAllocator`. The scheduler gates admission on `PageAllocator.free_count`
so a full pool leaves the queue pending instead of corrupting live rows.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------ config
@dataclasses.dataclass(frozen=True)
class KVStoreConfig:
    """Hashable store descriptor — part of every jit-cache key that traces
    differently per backend."""

    backend: str = "dense"        # "dense" | "paged"
    page_size: int = 0            # tokens per page (0 -> model's nsa.sel_block)
    num_pages: int = 0            # physical pool pages (0 -> slots * max_pages)

    def __post_init__(self):
        if self.backend not in ("dense", "paged"):
            raise ValueError(f"unknown kv backend {self.backend!r}; "
                             "choose dense or paged")

    @property
    def is_paged(self) -> bool:
        return self.backend == "paged"

    def resolved_page_size(self, model_cfg) -> int:
        ps = self.page_size or (model_cfg.nsa.sel_block
                                if model_cfg.attention == "nsa" else 64)
        if model_cfg.attention == "nsa" and ps % model_cfg.nsa.sel_block:
            raise ValueError(
                f"page_size={ps} must be a multiple of nsa.sel_block="
                f"{model_cfg.nsa.sel_block}: selected-block gather resolves "
                "through the page table, so pages must tile selection blocks")
        return ps

    def logical_pages(self, max_len: int, page_size: int) -> int:
        if max_len % page_size:
            raise ValueError(f"max_context={max_len} must be a multiple of "
                             f"page_size={page_size}")
        return max_len // page_size

    def resolved_num_pages(self, num_slots: int, max_pages_row: int) -> int:
        return self.num_pages or num_slots * max_pages_row


DENSE = KVStoreConfig()


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages covering ``n_tokens`` committed tokens (at least one page so an
    admitted row always owns a write target)."""
    return max(1, -(-int(n_tokens) // int(page_size)))


# ------------------------------------------------------------------ view
@dataclasses.dataclass
class KVView:
    """Per-layer K/V storage handle.

    dense: k/v are ``(B, S, Hkv, Dh)``, ``pages is None``.
    paged: k/v are the pool ``(P, page_size, Hkv, Dh)`` and ``pages`` is the
    shared ``(B, max_pages)`` int32 page table.
    """

    k: Any
    v: Any
    pages: Any = None

    # ---- static geometry (shapes only — safe under tracing)
    @property
    def is_paged(self) -> bool:
        return self.pages is not None

    @property
    def page_size(self) -> int:
        return self.k.shape[1]

    @property
    def max_len(self) -> int:
        if self.is_paged:
            return self.pages.shape[1] * self.page_size
        return self.k.shape[1]

    @property
    def batch(self) -> int:
        return self.pages.shape[0] if self.is_paged else self.k.shape[0]

    # ---- paged address resolution
    def _phys_flat(self, tok):
        """tok (B, ...) absolute positions -> flat pool-token index, -1 for
        out-of-range / unmapped (explicit zero page downstream)."""
        ps = self.page_size
        B = self.pages.shape[0]
        MP = self.pages.shape[1]
        valid = (tok >= 0) & (tok < MP * ps)
        lp = jnp.clip(tok // ps, 0, MP - 1)
        phys = jnp.take_along_axis(self.pages, lp.reshape(B, -1),
                                   axis=1).reshape(lp.shape)
        flat = phys * ps + tok % ps
        return jnp.where(valid & (phys >= 0), flat, -1)

    # ---- reads
    def gather_tokens(self, tok):
        """tok (B, *rest) absolute positions -> (k, v) of shape
        (B, *rest, Hkv, Dh); invalid positions read exact zeros."""
        if self.is_paged:
            flat = self._phys_flat(tok)
            P, ps = self.k.shape[0], self.page_size
            kf = self.k.reshape(P * ps, *self.k.shape[2:])
            vf = self.v.reshape(P * ps, *self.v.shape[2:])
            ok = (flat >= 0)[..., None, None]
            idx = jnp.clip(flat, 0, P * ps - 1)
            return jnp.where(ok, kf[idx], 0), jnp.where(ok, vf[idx], 0)
        S = self.k.shape[1]
        ok = ((tok >= 0) & (tok < S))[..., None, None]
        idx = jnp.clip(tok, 0, S - 1)
        B = self.k.shape[0]
        bidx = jnp.arange(B).reshape((B,) + (1,) * (tok.ndim - 1))
        return (jnp.where(ok, self.k[bidx, idx], 0),
                jnp.where(ok, self.v[bidx, idx], 0))

    def gather_blocks(self, idx, sel_block: int):
        """Selected-block gather (head-aligned): idx (B, T, Hkv, n) block
        indices -> k/v (B, T, Hkv, n, sel_block, Dh).

        Paged: a block index is a page-table lookup (pages tile sel blocks).
        Invalid / out-of-range / unmapped blocks read an explicit zero page —
        never a clamped neighbor (see tests/test_kvstore.py adversarial sel).
        """
        B, T, Hkv, n = idx.shape
        tok = idx[..., None] * sel_block + jnp.arange(sel_block)  # (B,T,Hkv,n,l')
        if self.is_paged:
            flat = self._phys_flat(tok)
            P, ps = self.k.shape[0], self.page_size
            kf = self.k.reshape(P * ps, *self.k.shape[2:])       # (P*ps, Hkv, Dh)
            vf = self.v.reshape(P * ps, *self.v.shape[2:])
            ok = (flat >= 0)[..., None]
            fidx = jnp.clip(flat, 0, P * ps - 1)
            hidx = jnp.arange(Hkv).reshape(1, 1, Hkv, 1, 1)
            return (jnp.where(ok, kf[fidx, hidx], 0),
                    jnp.where(ok, vf[fidx, hidx], 0))
        S = self.k.shape[1]
        ok = ((tok >= 0) & (tok < S))[..., None]
        tokc = jnp.clip(tok, 0, S - 1)
        bidx = jnp.arange(B).reshape(B, 1, 1, 1, 1)
        hidx = jnp.arange(Hkv).reshape(1, 1, Hkv, 1, 1)
        return (jnp.where(ok, self.k[bidx, tokc, hidx], 0),
                jnp.where(ok, self.v[bidx, tokc, hidx], 0))

    def window(self, win_start, W: int):
        """Trailing window [win_start, win_start + W) -> k/v (B, W, Hkv, Dh).
        Dense reproduces the seed's dynamic slice exactly; paged gathers the
        covering logical pages and slices the offset."""
        if not self.is_paged:
            return (jax.lax.dynamic_slice_in_dim(self.k, win_start, W, axis=1),
                    jax.lax.dynamic_slice_in_dim(self.v, win_start, W, axis=1))
        ps = self.page_size
        MP = self.pages.shape[1]
        # covering pages: W tokens starting at any in-page offset (up to
        # ps-1) span ceil(W/ps) + 1 logical pages in the worst case — NOT
        # W//ps + 1, which under-covers whenever W % ps != 0 and the offset
        # is large (regression: tests/test_kvstore.py window sweep)
        npg = min(-(-W // ps) + 1, MP)
        lp0 = jnp.clip(win_start // ps, 0, MP - npg)
        pg = jax.lax.dynamic_slice_in_dim(self.pages, lp0, npg, axis=1)
        P = self.k.shape[0]
        ok = (pg >= 0)[..., None, None, None]
        pgc = jnp.clip(pg, 0, P - 1)
        kw = jnp.where(ok, self.k[pgc], 0)                        # (B,npg,ps,H,D)
        vw = jnp.where(ok, self.v[pgc], 0)
        B = kw.shape[0]
        kw = kw.reshape(B, npg * ps, *kw.shape[3:])
        vw = vw.reshape(B, npg * ps, *vw.shape[3:])
        off = win_start - lp0 * ps
        return (jax.lax.dynamic_slice_in_dim(kw, off, W, axis=1),
                jax.lax.dynamic_slice_in_dim(vw, off, W, axis=1))

    def full(self):
        """Materialize the logical (B, max_len, Hkv, Dh) view — the dense
        fallback for whole-cache readers (dense-attention draft layers).
        Unmapped pages read zeros; callers mask by prefix length anyway."""
        if not self.is_paged:
            return self.k, self.v
        P = self.k.shape[0]
        ok = (self.pages >= 0)[..., None, None, None]
        pgc = jnp.clip(self.pages, 0, P - 1)
        kf = jnp.where(ok, self.k[pgc], 0)                        # (B,MP,ps,H,D)
        vf = jnp.where(ok, self.v[pgc], 0)
        B, MP = self.pages.shape
        return (kf.reshape(B, MP * self.page_size, *kf.shape[3:]),
                vf.reshape(B, MP * self.page_size, *vf.shape[3:]))

    # ---- writes
    def write(self, k_new, v_new, start, row_mask=None):
        """Insert (B, T, Hkv, Dh) at position ``start`` (scalar, or (B,) for
        paged). Returns the new (k, v) storage. Paged writes resolve through
        the page table; rows with ``row_mask == False`` (released slots whose
        pages may already belong to someone else) and positions past the
        row's mapped pages are dropped, not clamped. ``row_mask`` is a
        paged-only concept — the dense layout has no page recycling to
        guard, so supplying one is a caller bug and raises rather than being
        silently ignored."""
        if not self.is_paged:
            if row_mask is not None:
                raise ValueError("row_mask is only meaningful for the paged "
                                 "backend; dense writes are never dropped")
            k = jax.lax.dynamic_update_slice_in_dim(
                self.k, k_new.astype(self.k.dtype), start, axis=1)
            v = jax.lax.dynamic_update_slice_in_dim(
                self.v, v_new.astype(self.v.dtype), start, axis=1)
            return k, v
        B, T = k_new.shape[:2]
        start = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (B,))
        pos = start[:, None] + jnp.arange(T)                      # (B, T)
        flat = self._phys_flat(pos)
        if row_mask is not None:
            flat = jnp.where(row_mask[:, None], flat, -1)
        P, ps = self.k.shape[0], self.page_size
        kf = self.k.reshape(P * ps, *self.k.shape[2:])
        vf = self.v.reshape(P * ps, *self.v.shape[2:])
        # mode="drop" only discards indices PAST the end — negatives would
        # wrap python-style onto the last page — so invalid writes are
        # redirected to a past-the-end sentinel first
        fidx = jnp.where(flat >= 0, flat, P * ps).reshape(-1)
        kf = kf.at[fidx].set(k_new.reshape((B * T,) + k_new.shape[2:]
                                           ).astype(kf.dtype), mode="drop")
        vf = vf.at[fidx].set(v_new.reshape((B * T,) + v_new.shape[2:]
                                           ).astype(vf.dtype), mode="drop")
        return kf.reshape(self.k.shape), vf.reshape(self.v.shape)


jax.tree_util.register_pytree_node(
    KVView,
    lambda s: ((s.k, s.v, s.pages), None),
    lambda _, ch: KVView(*ch))


def as_view(kv, pages=None) -> KVView:
    """Normalize a raw ``{"k", "v"}`` cache dict (seed call sites) or an
    existing view into a KVView bound to ``pages``."""
    if isinstance(kv, KVView):
        return kv
    return KVView(kv["k"], kv["v"], pages)


# ------------------------------------------------------------------ init
def init_kv(cfg, batch: int, max_len: int, dtype, store: KVStoreConfig):
    """Per-layer K/V storage leaves for one block."""
    if not store.is_paged:
        return {"k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)}
    ps = store.resolved_page_size(cfg)
    mp = store.logical_pages(max_len, ps)
    P = store.resolved_num_pages(batch, mp)
    return {"k": jnp.zeros((P, ps, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((P, ps, cfg.num_kv_heads, cfg.head_dim), dtype)}


def empty_page_table(batch: int, max_pages: int):
    return jnp.full((batch, max_pages), -1, jnp.int32)


# ------------------------------------------------------------------ structure
def map_segments(segs, f_kv: Callable, f_other: Callable):
    """Apply ``f_kv`` to raw-KV leaves and ``f_other`` to every other cache
    leaf (cmp / recurrent state), preserving the segments structure. This is
    how backend-split treatments (pool leaves have no batch axis; cmp/state
    leaves do) thread through vmap in_axes, admissions, and commits."""
    out = []
    for seg in segs:
        group = []
        for c in seg:
            d = {}
            for key, sub in c.items():
                d[key] = jax.tree.map(f_kv if key == "kv" else f_other, sub)
            group.append(d)
        out.append(tuple(group))
    return out


def kv_cache_bytes(segs) -> int:
    """Raw-KV footprint of a segments pytree (pool or dense leaves) — the
    peak-KV-bytes metric benchmarks report per serving row."""
    total = 0
    for seg in segs:
        for c in seg:
            if "kv" in c:
                total += sum(int(np.prod(a.shape)) * a.dtype.itemsize
                             for a in jax.tree.leaves(c["kv"]))
    return total


# ------------------------------------------------------------------ admission
@functools.partial(jax.jit, donate_argnums=(0,))
def admit_row_paged(batch_segs, row_segs, row, pages_row):
    """Paged counterpart of ``engine.admit_row_segments``: land a freshly
    prefilled single-request cache into batch row ``row``.

    Raw-KV leaves of ``row_segs`` are dense ``(n, 1, S, Hkv, Dh)`` (prefill
    stays dense — one transient request-sized buffer); they are re-blocked
    into logical pages and scattered into the shared pool at the row's
    physical pages (``pages_row`` (MP,), -1 entries dropped). cmp /
    recurrent leaves are written in place at batch row ``row`` exactly like
    the dense admission path. ``batch_segs`` is donated — no copy of other
    rows, and pool pages owned by other rows are untouched by construction
    (the allocator never double-assigns)."""
    def land_kv(pool, dense):
        ps = pool.shape[2]
        n, _, S = dense.shape[:3]
        P = pool.shape[1]
        mp = S // ps
        blocked = dense.reshape((n, mp, ps) + dense.shape[3:])
        # unmapped (-1) entries must go past the end: mode="drop" wraps
        # negatives onto the last page instead of dropping them
        phys = jnp.where(pages_row >= 0, pages_row, P)
        write = lambda p, b: p.at[phys].set(b.astype(p.dtype), mode="drop")
        return jax.vmap(write)(pool, blocked)

    def land_row(b, s):
        return jax.lax.dynamic_update_slice_in_dim(
            b, s.astype(b.dtype), row, axis=1)

    return map_segments2(batch_segs, row_segs, land_kv, land_row)


def map_segments2(segs_a, segs_b, f_kv, f_other):
    """Two-tree variant of ``map_segments`` (same structure on both sides)."""
    out = []
    for seg_a, seg_b in zip(segs_a, segs_b):
        group = []
        for ca, cb in zip(seg_a, seg_b):
            d = {}
            for key in ca:
                fn = f_kv if key == "kv" else f_other
                d[key] = jax.tree.map(fn, ca[key], cb[key])
            group.append(d)
        out.append(tuple(group))
    return out


# ------------------------------------------------------------------ allocator
class PageAllocator:
    """Host-side free-list page allocator.

    Invariants (property-tested in tests/test_kvstore.py):
      * a page is owned by at most one allocation at a time;
      * ``alloc`` returns ``None`` — and changes nothing — when the pool
        cannot satisfy the request (callers keep the request queued);
      * ``free`` rejects pages that are not currently allocated (double-free
        and foreign-page bugs surface as errors, not silent corruption).
    """

    def __init__(self, num_pages: int):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))  # pop() -> 0,1,2,...
        self._allocated: set = set()

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return len(self._allocated)

    @property
    def occupancy(self) -> float:
        return len(self._allocated) / self.num_pages

    def can_alloc(self, n: int) -> bool:
        return 0 < n <= len(self._free)

    def alloc(self, n: int) -> Optional[np.ndarray]:
        """n physical pages, or None (state unchanged) if the pool is
        exhausted — admission then leaves the request pending."""
        if n < 1:
            raise ValueError(f"alloc of {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        return np.asarray(pages, np.int32)

    def free(self, pages: Sequence[int]) -> None:
        pages = [int(p) for p in np.asarray(pages).reshape(-1)]
        for p in pages:
            if p not in self._allocated:
                raise ValueError(f"freeing page {p} that is not allocated")
        for p in pages:
            self._allocated.remove(p)
            self._free.append(p)

