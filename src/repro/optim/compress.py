"""Gradient compression for cross-pod reduction: int8 quantization with
per-tensor scales, deterministic-stochastic rounding, and error feedback.

On a (pod, data, model) mesh, data-parallel gradient reduction over the
*pod* axis crosses the slow inter-pod links; quantizing to int8 cuts that
wire traffic 2x vs bf16 / 4x vs f32. Error feedback (residual carried in the
optimizer state) keeps the scheme convergent (Karimireddy et al., 2019).

``compress_pytree``/``decompress_pytree`` are mesh-agnostic: the train step
applies them around the pod-axis psum inside shard_map, or — in the pure-pjit
path used by the dry-run — around the gradient tree as a fidelity-equivalent
simulation (the quantization error is identical; only the wire format is
simulated). EXPERIMENTS.md §Perf reports the collective-bytes effect.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _quantize(x, key):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    # stochastic rounding, deterministic per (key, tensor)
    noise = jax.random.uniform(key, x.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def compress_pytree(grads, residual, step: jnp.ndarray):
    """-> (quantized tree (int8 leaves + scales), new residual)."""
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = jax.tree.leaves(residual)
    base = jax.random.PRNGKey(0)
    qs, scales, new_res = [], [], []
    for i, (g, r) in enumerate(zip(leaves, res_leaves)):
        key = jax.random.fold_in(jax.random.fold_in(base, i), step)
        corrected = g.astype(jnp.float32) + r
        q, s = _quantize(corrected, key)
        qs.append(q)
        scales.append(s)
        new_res.append(corrected - q.astype(jnp.float32) * s)
    return (jax.tree.unflatten(treedef, qs), jax.tree.unflatten(treedef, scales)), \
        jax.tree.unflatten(treedef, new_res)


def decompress_pytree(quantized) -> object:
    qs, scales = quantized
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, scales)


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
