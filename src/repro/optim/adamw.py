"""AdamW + schedules + global-norm clipping, pure JAX (no optax dependency).

State layout mirrors optax ((mu, nu, count)) so checkpoints stay simple
pytrees. Weight decay is decoupled and skipped for 1-D parameters (norms,
biases, gate vectors) per standard practice.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class AdamWState(NamedTuple):
    mu: object
    nu: object
    count: jnp.ndarray


def cosine_schedule(cfg: TrainConfig) -> Callable:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
        prog = jnp.clip((step - cfg.warmup_steps) /
                        max(cfg.steps - cfg.warmup_steps, 1), 0.0, 1.0)
        return cfg.learning_rate * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return f


def linear_schedule(cfg: TrainConfig) -> Callable:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
        prog = jnp.clip((step - cfg.warmup_steps) /
                        max(cfg.steps - cfg.warmup_steps, 1), 0.0, 1.0)
        return cfg.learning_rate * warm * (1 - 0.9 * prog)
    return f


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), p)
    return AdamWState(mu=zeros(params), nu=zeros(params),
                      count=jnp.zeros((), jnp.int32))


def adamw_update(grads, state: AdamWState, params, cfg: TrainConfig,
                 schedule: Optional[Callable] = None):
    sched = schedule or cosine_schedule(cfg)
    count = state.count + 1
    lr = sched(count - 1)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                      jnp.square(g.astype(jnp.float32)), state.nu, grads)
    c = count.astype(jnp.float32)
    mu_hat_scale = 1.0 / (1 - b1 ** c)
    nu_hat_scale = 1.0 / (1 - b2 ** c)

    def upd(p, m, v):
        step = m * mu_hat_scale / (jnp.sqrt(v * nu_hat_scale) + 1e-8)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=count)
