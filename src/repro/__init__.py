"""repro — production-grade JAX reproduction of SSV (Sparse Speculative
Verification for Efficient LLM Inference) with a multi-architecture model
zoo, Pallas TPU verification kernels, a fault-tolerant distributed runtime,
and a 512-chip multi-pod dry-run + roofline methodology."""
__version__ = "1.0.0"
