"""Training driver CLI.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ck

Full-scale configs train on the production mesh (``--mesh single|multipod``
requires real hardware or the dry-run device override); ``--reduced`` runs
the CI-scale family variant on local devices.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from repro import configs as cfglib
from repro.config import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = cfglib.reduced(args.arch) if args.reduced else cfglib.get_config(args.arch)
    tcfg = TrainConfig(steps=args.steps, learning_rate=args.lr,
                       micro_batches=args.micro_batches,
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt, seed=args.seed,
                       grad_compression="int8_ef" if args.compress else "none")

    from repro.runtime.trainer import Trainer  # import after arg parsing
    tr = Trainer(cfg, tcfg, batch_size=args.batch, seq_len=args.seq)
    print(f"training {cfg.name}: {cfg.param_count():,} params, "
          f"resume step {tr.state.step}")
    tr.run()
    for m in tr.metrics_log[-5:]:
        print(json.dumps(m))
    print(f"done at step {tr.state.step}; straggler events: "
          f"{len(tr.watchdog.events)}")


if __name__ == "__main__":
    main()
