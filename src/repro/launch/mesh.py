"""Production meshes.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
carries cross-pod data parallelism (gradient reduction over the slower DCI
links; see optim/compress.py for the int8 path).

``make_production_mesh`` is a FUNCTION — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax

from repro.config import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes,
                         devices=jax.devices()[:n],
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    if multi_pod:
        return MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))
    return MeshConfig(shape=(16, 16), axes=("data", "model"))


def dp_axes(mesh) -> tuple:
    """Data-parallel axes: everything except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CI (requires XLA_FLAGS host device override)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
