"""ShapeDtypeStruct input specs + step builders for every
(architecture × input-shape × mesh) dry-run cell.

``build_cell`` returns (step_fn, args (SDS with shardings), out_shardings,
cfg) — everything ``dryrun.py`` needs to ``jit(...).lower(...).compile()``
without allocating a single real array.

Shape semantics (assignment):
  train_4k / prefill_32k -> train_step / prefill_step over the arch's NATIVE
      attention;
  decode_32k             -> serve_step (1 new token, 32K KV cache), native;
  long_500k              -> serve_step at 524,288 context — run with the NSA
      backend for attention archs (dense full-attention is skipped per the
      assignment; the paper's sparse attention is exactly what unlocks this
      cell) and natively for SSM/hybrid archs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as cfglib
from repro.config import ModelConfig, ShapeConfig, TrainConfig, SHAPES
from repro.launch import sharding as shd
from repro.models import model
from repro.optim import adamw_init
from repro.runtime.trainer import make_train_step

SHAPE_BY_NAME = {s.name: s for s in SHAPES}
CACHE_SLACK = 512


def sds(shape, dtype, mesh=None, spec: Optional[P] = None):
    shard = NamedSharding(mesh, spec) if mesh is not None and spec is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=shard)


def _with_shardings(tree_sds, spec_tree, mesh):
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree_sds, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cell_config(arch_id: str, shape_name: str, opt: bool = False
                ) -> Tuple[ModelConfig, Dict]:
    cfg = cfglib.get_config(arch_id)
    over = cfglib.dryrun_overrides(arch_id).get(shape_name, {})
    if over.get("nsa"):
        cfg = cfglib.nsa_variant(cfg)
    if opt and cfg.attention in ("dense", "swa"):
        # §Perf beyond-paper optimization (iteration 4 winner): per-chunk
        # remat of the attention scan — kills the stacked probability
        # residual buffers. (Iterations 1-3 — online softmax, custom-VJP
        # flash, d-sharded layout — are kept selectable via attention_impl;
        # see EXPERIMENTS.md §Perf for the refutation log.)
        cfg = dataclasses.replace(cfg, attention_impl="chunked_remat")
    return cfg, over


def params_sds(cfg: ModelConfig, mesh):
    tree = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), cfg))
    specs = shd.param_specs(cfg, tree, mesh)
    return _with_shardings(tree, specs, mesh), specs


def build_cell(arch_id: str, shape_name: str, mesh, mesh_name: str,
               opt: bool = False):
    """-> (step_fn, args tuple of SDS, out_shardings, cfg)."""
    shape = SHAPE_BY_NAME[shape_name]
    cfg, over = cell_config(arch_id, shape_name, opt=opt)
    dp = tuple(a for a in mesh.axis_names if a != "model")
    p_sds, p_specs = params_sds(cfg, mesh)
    flen = cfglib.frontend_len(arch_id)

    if shape.kind == "train":
        mb = over.get("micro_batches_opt", over.get("micro_batches", 1)) if opt \
            else over.get("micro_batches", 1)
        tcfg = TrainConfig(micro_batches=mb, remat=True)
        constrain = shd.activation_constraint(mesh)
        raw = make_train_step(cfg, tcfg, donate=False, jit=False,
                              constrain=constrain)
        opt_t = jax.eval_shape(adamw_init, p_sds)
        opt_specs = type(opt_t)(mu=p_specs, nu=p_specs, count=P())
        opt_sds = _with_shardings(opt_t, opt_specs, mesh)
        res_sds = sds((), jnp.float32, mesh, P())
        toks = sds((shape.global_batch, shape.seq_len), jnp.int32, mesh,
                   P(dp, None))

        if flen:
            def step(params, opt, residual, tokens, frontend):
                def lf(p, b):
                    return model.loss_fn(p, cfg, b, frontend=frontend,
                                         remat=True, constrain=constrain)
                import jax as _jax
                loss, grads = _jax.value_and_grad(lf)(params, tokens)
                from repro.optim import adamw_update, clip_by_global_norm
                grads, gn = clip_by_global_norm(grads, tcfg.grad_clip)
                params, opt = adamw_update(grads, opt, params, tcfg)
                return params, opt, residual, {"loss": loss, "grad_norm": gn}
            fe = sds((shape.global_batch, flen, cfg.frontend_dim),
                     jnp.bfloat16, mesh, P(dp, None, None))
            args = (p_sds, opt_sds, res_sds, toks, fe)
        else:
            step = raw
            args = (p_sds, opt_sds, res_sds, toks)
        out_shardings = (
            _shardings(p_specs, mesh), _shardings(opt_specs, mesh),
            NamedSharding(mesh, P()),
            {"loss": NamedSharding(mesh, P()),
             "grad_norm": NamedSharding(mesh, P())})
        return step, args, out_shardings, cfg

    if shape.kind == "prefill":
        constrain = shd.activation_constraint(mesh)
        max_len = shape.seq_len + CACHE_SLACK

        def prefill_step(params, tokens, frontend=None):
            hidden, caches = model.prefill(params, cfg, tokens, max_len,
                                           frontend=frontend,
                                           constrain=constrain)
            logits = model.logits_fn(params, cfg, hidden[:, -1:])
            return logits, caches

        toks = sds((shape.global_batch, shape.seq_len), jnp.int32, mesh,
                   P(dp, None))
        caches_t = jax.eval_shape(
            lambda: model.init_caches(cfg, shape.global_batch, max_len))
        c_specs = shd.cache_specs(cfg, caches_t, mesh, shard_sequence=False)
        out_shardings = (NamedSharding(mesh, P(dp, None, "model")),
                         _shardings(c_specs, mesh))
        if flen:
            fe = sds((shape.global_batch, flen, cfg.frontend_dim),
                     jnp.bfloat16, mesh, P(dp, None, None))
            return prefill_step, (p_sds, toks, fe), out_shardings, cfg
        return (lambda params, tokens: prefill_step(params, tokens)), \
            (p_sds, toks), out_shardings, cfg

    # decode
    max_len = shape.seq_len + CACHE_SLACK
    shard_seq = shape.global_batch == 1

    if opt and shard_seq and cfg.attention == "nsa":
        # §Perf: split-KV sequence-sharded NSA decode (models/nsa_sharded.py)
        from repro.models import nsa_sharded
        seq_axes = tuple(mesh.axis_names)

        def serve_step(params, caches, tokens):
            return nsa_sharded.decode_step_sharded(params, cfg, mesh, caches,
                                                   tokens, seq_axes)
    else:
        def serve_step(params, caches, tokens):
            return model.decode_step(params, cfg, caches, tokens)

    caches_t = jax.eval_shape(
        lambda: model.init_caches(cfg, shape.global_batch, max_len))
    caches_t = jax.tree.map(
        lambda t: t, caches_t,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    # dry-run semantics: the cache is FULL to seq_len
    c_specs = shd.cache_specs(cfg, caches_t, mesh, shard_sequence=shard_seq)
    c_sds = _with_shardings(caches_t, c_specs, mesh)
    # length is a scalar int32 inside the cache tree (spec P())
    toks = sds((shape.global_batch, 1), jnp.int32, mesh,
               P(dp, None) if shape.global_batch > 1 else P(None, None))
    logit_spec = P(dp, None, "model") if shape.global_batch > 1 else \
        P(None, None, "model")
    out_shardings = (NamedSharding(mesh, logit_spec), _shardings(c_specs, mesh))
    return serve_step, (p_sds, c_sds, toks), out_shardings, cfg


def _shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
