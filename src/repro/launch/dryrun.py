import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell on placeholder devices, record memory/cost/collective artifacts.

The two lines above MUST precede any other import (jax locks the device
count on first initialization).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k --mesh single
  ... --force     re-run cells whose artifact already exists
  ... --list      print the cell matrix and exit

Artifacts land in artifacts/dryrun/<mesh>/<arch>__<shape>.json and are the
inputs to analysis/roofline.py + EXPERIMENTS.md §Dry-run/§Roofline.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro import configs as cfglib
from repro.analysis import hlo as hlo_lib
from repro.analysis import roofline as rl
from repro.config import SHAPES
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def run_cell(arch_id: str, shape_name: str, mesh_name: str, out_dir: str,
             force: bool = False, save_hlo: bool = False,
             opt: bool = False) -> dict:
    path = os.path.join(out_dir, mesh_name, f"{arch_id}__{shape_name}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    t0 = time.time()
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "status": "error"}
    try:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
        ndev = mesh.devices.size
        step, args, out_shardings, cfg = specs_lib.build_cell(
            arch_id, shape_name, mesh, mesh_name, opt=opt)
        with mesh:
            lowered = jax.jit(step, out_shardings=out_shardings).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        ana = hlo_lib.analyze(txt, num_devices=ndev)
        shape = specs_lib.SHAPE_BY_NAME[shape_name]
        roof = rl.build(arch_id, shape, mesh_name, ndev, cfg, ana,
                        mem_bytes_per_dev=(mem.argument_size_in_bytes +
                                           mem.output_size_in_bytes +
                                           mem.temp_size_in_bytes))
        rec.update({
            "status": "ok",
            "devices": ndev,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            },
            "cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed") if k in cost},
            "hlo": {
                "flops_per_dev": ana.flops,
                "hbm_bytes_per_dev": ana.hbm_bytes,
                "collective_bytes": ana.collective_bytes,
                "collective_wire_bytes": ana.collective_wire_bytes,
                "collective_counts": ana.collective_counts,
            },
            "roofline": roof.row(),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "config_name": cfg.name,
        })
        if save_hlo:
            with open(path.replace(".json", ".hlo.txt"), "w") as f:
                f.write(txt)
    except Exception as e:  # record the failure — these are bugs to fix
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply beyond-paper optimizations (online attention); "
                         "writes artifacts to <out>_opt")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ART_DIR))
    args = ap.parse_args()

    archs = list(cfglib.ASSIGNED) if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in SHAPES] if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multipod"] if args.mesh == "both" else [args.mesh]

    if args.opt:
        args.out = args.out.rstrip("/") + "_opt"
    cells = [(a, s, m) for m in meshes for a in archs for s in shapes]
    if args.list:
        for c in cells:
            print(*c)
        print(f"{len(cells)} cells")
        return

    n_ok = n_fail = 0
    for a, s, m in cells:
        rec = run_cell(a, s, m, args.out, force=args.force,
                       save_hlo=args.save_hlo, opt=args.opt)
        ok = rec.get("status") == "ok"
        n_ok += ok
        n_fail += (not ok)
        if ok:
            r = rec["roofline"]
            print(f"[OK]   {m:8s} {a:24s} {s:12s} "
                  f"compile={rec.get('compile_s', '?')}s "
                  f"bottleneck={r['bottleneck']:10s} "
                  f"step={max(r['compute_s'], r['memory_s'], r['collective_s']):.4f}s "
                  f"mem/dev={rec['memory']['argument_bytes'] / 2**30 + rec['memory']['temp_bytes'] / 2**30:.2f}GiB",
                  flush=True)
        else:
            print(f"[FAIL] {m:8s} {a:24s} {s:12s} {rec.get('error', '')[:160]}",
                  flush=True)
    print(f"\n{n_ok} ok, {n_fail} failed")


if __name__ == "__main__":
    main()
