"""Serving driver CLI: SSV speculative serving of an architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch ssv-nsa-1b --reduced \
      --tokens 64 --precision-class Approx+Reuse
  PYTHONPATH=src python -m repro.launch.serve --reduced --continuous \
      --batch 4 --bucketed --profile-json profile.json --warmup

Loads (or randomly initializes) target + draft, builds a small offline
profile if planning is requested, and serves a batch of synthetic prompts,
reporting accepted-token throughput vs the autoregressive baseline.
``--bucketed`` serves a mixed-length workload through bucket-local
execution groups (one fused step per context-regime bucket, each under the
profile's strategy for that bucket — the profile JSON is a
``planner_lib.Profile`` from ``Profile.to_json``); ``--warmup``
AOT-compiles every reachable (strategy, group size) step before serving.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs as cfglib
from repro.config import ServeConfig, SSVConfig
from repro.core import draft as draft_lib
from repro.core import engine as engine_lib
from repro.core import planner as planner_lib
from repro.core import schedule as schedule_lib
from repro.data.synthetic import SyntheticConfig, SyntheticCorpus
from repro.models import model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ssv-nsa-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--prompts", type=int, default=2)
    ap.add_argument("--batch", type=int, default=1,
                    help=">1 serves all prompts through the vectorized "
                         "BatchedSSVEngine in one fused step per iteration")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over --batch slots: admit "
                         "prompts into freed slots mid-flight (Poisson "
                         "arrival replay via --arrival-rate) instead of "
                         "serving drain-then-refill groups")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="continuous-mode Poisson arrivals per fused step "
                         "(<=0: all requests arrive at t=0)")
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--kv-backend", default="dense", choices=("dense", "paged"),
                    help="KV-cache store (core/kvstore.py): dense keeps "
                         "per-slot max_context buffers; paged shares a "
                         "physical page pool across requests via per-row "
                         "page tables, so serving memory scales with live "
                         "tokens — pair with --kv-num-pages to cap the pool")
    ap.add_argument("--kv-page-size", type=int, default=0,
                    help="tokens per KV page (0 = the model's nsa.sel_block, "
                         "which makes selected-block gather a page-table "
                         "lookup; must be a sel_block multiple)")
    ap.add_argument("--kv-num-pages", type=int, default=0,
                    help="physical pages in the shared pool (0 = worst-case "
                         "slots*max_context/page_size — no memory win; size "
                         "it to expected live tokens and the scheduler "
                         "admits on free-page headroom)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--precision-class", default="Strict",
                    choices=list(planner_lib.PRECISION_CLASSES))
    ap.add_argument("--tree-depth", type=int, default=4)
    ap.add_argument("--tree-width", type=int, default=2)
    ap.add_argument("--bucketed", action="store_true",
                    help="continuous mode: partition the batch into context-"
                         "regime execution groups, each stepping under its "
                         "bucket's profile strategy (needs --profile-json); "
                         "serves a mixed-length prompt workload")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile every reachable (strategy, group size) "
                         "fused step before serving (bucketed only)")
    ap.add_argument("--profile-json", default=None,
                    help="offline profile (planner_lib.Profile JSON, e.g. "
                         "written via Profile.to_json) backing --bucketed")
    ap.add_argument("--baseline", action="store_true",
                    help="also run the autoregressive decode baseline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.bucketed:
        if not args.continuous:
            raise ValueError("--bucketed groups the continuous batch; "
                             "add --continuous")
        if not args.profile_json:
            raise ValueError(
                "--bucketed needs an offline profile to rank strategies per "
                "context bucket: pass --profile-json <path> (a "
                "planner_lib.Profile serialized with Profile.to_json)")
    if args.warmup and not args.bucketed:
        raise ValueError("--warmup pre-compiles the bucketed group-step "
                         "cache; add --bucketed")

    cfg = cfglib.reduced(args.arch) if args.reduced else cfglib.get_config(args.arch)
    if cfg.attention != "nsa":
        cfg = cfglib.nsa_variant(cfg) if cfg.d_ff or cfg.block_pattern == ("attn",) else cfg
    dcfg = draft_lib.draft_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    tp = model.init(key, cfg)
    dp = model.init(jax.random.fold_in(key, 1), dcfg)

    mode, reuse = planner_lib.class_constraints(args.precision_class)
    sched = planner_lib.default_schedule(cfg.num_layers) if reuse else ()
    ssv = SSVConfig(tree_depth=args.tree_depth, tree_width=args.tree_width,
                    group_size=4 if mode == "approx" else 2, group_mode=mode,
                    refresh_schedule=sched,
                    precision_class=args.precision_class)
    serve_cfg = ServeConfig(max_new_tokens=args.tokens,
                            temperature=args.temperature,
                            max_context=min(cfg.max_seq_len, 2048), ssv=ssv,
                            use_planner=False,
                            kv_backend=args.kv_backend,
                            kv_page_size=args.kv_page_size,
                            kv_num_pages=args.kv_num_pages)

    corpus = SyntheticCorpus(SyntheticConfig(vocab_size=cfg.vocab_size))
    if args.bucketed:
        # mixed-length workload: spread prompt lengths across the profile's
        # context buckets so the planner actually forms several groups
        lens = [max(8, args.prompt_len // 2), args.prompt_len,
                args.prompt_len * 2]
        prompts = [corpus.batch(i, 1, lens[i % len(lens)])[0]
                   for i in range(args.prompts)]
    else:
        prompts = [corpus.batch(i, 1, args.prompt_len)[0]
                   for i in range(args.prompts)]

    if args.continuous:     # any batch size: --batch is the slot count
        planner = None
        if args.bucketed:
            with open(args.profile_json) as f:
                profile = planner_lib.Profile.from_json(f.read())
            planner = planner_lib.BatchPlanner(profile, args.precision_class)
        eng = engine_lib.BatchedSSVEngine(tp, cfg, dp, dcfg, serve_cfg,
                                          planner=planner)
        arrivals = schedule_lib.poisson_arrivals(
            len(prompts), args.arrival_rate, seed=args.seed)
        reqs = [schedule_lib.Request(req_id=i, prompt=p,
                                     arrival=float(arrivals[i]))
                for i, p in enumerate(prompts)]
        res = eng.serve_continuous(reqs, num_slots=args.batch,
                                   max_new_tokens=args.tokens,
                                   warmup=args.warmup)
        for req, gen in zip(res.requests, res.results):
            delay = (f"{req.queue_delay:.1f}" if req.queue_delay is not None
                     else "n/a (never admitted)")
            print(f"prompt {req.req_id}: {len(gen.tokens)} tokens, "
                  f"arrival {req.arrival:.1f}, queue delay {delay} steps")
        print(f"continuous over {args.batch} slots: {res.total_tokens} tokens "
              f"in {res.wall_s:.2f}s ({res.aggregate_throughput:.1f} tok/s "
              f"aggregate, {res.steps} fused steps, "
              f"occupancy {res.mean_occupancy:.2f}, "
              f"queue delay {res.mean_queue_delay_steps:.1f} steps)")
        if args.bucketed:
            occ = ", ".join(f"bucket{b}={v:.2f}"
                            for b, v in sorted(res.bucket_occupancy.items()))
            print(f"bucketed: {res.group_launches} group launches ({occ}); "
                  f"step cache {res.kernel_cache['step_cache_hits']} hits / "
                  f"{res.kernel_cache['step_cache_misses']} misses")
        return

    if args.batch > 1:
        eng = engine_lib.BatchedSSVEngine(tp, cfg, dp, dcfg, serve_cfg)
        for lo in range(0, len(prompts), args.batch):
            group = prompts[lo : lo + args.batch]
            batch = eng.generate_batch(group, max_new_tokens=args.tokens)
            for i, res in enumerate(batch.results):
                print(f"prompt {lo + i}: {len(res.tokens)} tokens, "
                      f"mean accepted/step {res.mean_accepted:.2f}")
            print(f"batch[{lo}:{lo + len(group)}]: {batch.total_tokens} tokens in "
                  f"{batch.wall_s:.2f}s ({batch.aggregate_throughput:.1f} tok/s "
                  f"aggregate, {batch.steps} fused steps)")
        return

    eng = engine_lib.SSVEngine(tp, cfg, dp, dcfg, serve_cfg)
    for i, prompt in enumerate(prompts):
        res = eng.generate(prompt, max_new_tokens=args.tokens)
        print(f"prompt {i}: {len(res.tokens)} tokens, "
              f"mean accepted/step {res.mean_accepted:.2f}, "
              f"throughput {res.accepted_token_throughput:.1f} tok/s")
        if args.baseline:
            bl = engine_lib.autoregressive_decode(
                tp, cfg, prompt, len(res.tokens), serve_cfg.max_context,
                temperature=args.temperature)
            print(f"  AR baseline: {bl.accepted_token_throughput:.1f} tok/s "
                  f"-> speedup {res.accepted_token_throughput / max(bl.accepted_token_throughput, 1e-9):.2f}x")


if __name__ == "__main__":
    main()
