"""GSPMD sharding rules for the whole framework.

Strategy (MaxText-style 2D/3D):
  * TP over ``model``: attention heads, FFN hidden, vocab, MoE expert axis.
  * FSDP over every data-parallel axis (``data``, plus ``pod`` on the
    multi-pod mesh): each weight's non-TP matrix dim is sharded across DP;
    XLA all-gathers weights just-in-time inside the layer scan, so resident
    parameter (and optimizer-state) memory is O(params / n_devices).
  * DP: the batch is sharded over (pod × data); gradient reduction emerges
    as reduce-scatter/all-gather pairs from GSPMD.
  * SP: the residual stream carried between layers is sharded over ``model``
    along the sequence axis (``activation_constraint``) — this is what keeps
    remat-stored activations per device at seq·d/|model| (Megatron-SP).

Rules are path-pattern based so they cover every architecture's param tree
without per-arch tables. KV caches shard batch over DP and (for batch-1
long-context cells) sequence over DP.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig


def _dp(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _path_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(key: str, shape: Tuple[int, ...], mesh,
               stacked: bool = False) -> P:
    """Sharding rule for one parameter. ``stacked`` params carry a leading
    layer-group dim (never sharded — scan slices it)."""
    dp = _dp(mesh)
    lead: Tuple = (None,) if stacked else ()
    nd = len(shape) - len(lead)

    def spec(*rest):
        return P(*(lead + rest))

    # --- top-level tables
    if key.endswith("embed/table"):
        return P("model", None)
    if key.endswith("lm_head/w"):
        return P(None, "model")
    if "frontend_proj" in key:
        return P(None, None)

    # --- MoE experts: (E, d, f) / (E, f, d)
    if re.search(r"ffn/(w_up|w_gate)$", key) and nd == 3:
        return spec(None, dp, "model")
    if re.search(r"ffn/w_down$", key) and nd == 3:
        return spec(None, "model", dp)
    if key.endswith("ffn/router"):
        return spec(dp, None)

    # --- dense FFN (d, f) / (f, d)
    if re.search(r"ffn/(w_up|w_gate)$", key) and nd == 2:
        return spec(dp, "model")
    if re.search(r"ffn/w_down$", key) and nd == 2:
        return spec("model", dp)

    # --- attention projections
    if re.search(r"mix/(wq|wk|wv)$", key):
        return spec(dp, "model")
    if key.endswith("mix/wo"):
        return spec("model", dp)
    if key.endswith("mix/w_gate"):          # NSA branch gates (d, 3Hq)
        return spec(dp, None)
    if re.search(r"mix/w_cmp_[kv]$", key):
        return spec(None, None)

    # --- recurrent blocks
    if re.search(r"mix/(w_in|w_gate_branch|w_a|w_x|wq|wk|wv|wo_gate|w_x)$", key):
        return spec(dp, "model")
    if re.search(r"mix/(w_out|w_h)$", key):
        return spec("model", dp) if key.endswith("w_out") else spec(dp, "model")
    if key.endswith("mix/conv"):
        return spec(None, "model")
    if key.endswith("mix/lam"):
        return spec("model")
    if re.search(r"mix/(wi|wf)$", key):
        return spec(dp, None)

    # --- 1-D / small leaves (norm scales, biases, gate vectors, phis)
    return spec(*([None] * nd))


def param_specs(cfg: ModelConfig, params_tree, mesh):
    """Pytree of PartitionSpec matching ``params_tree`` (may be SDS tree)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = []
    for path, leaf in flat:
        key = _path_key(path)
        stacked = key.startswith("segments/")
        specs.append(param_spec(key, tuple(leaf.shape), mesh, stacked=stacked))
    return jax.tree_util.tree_unflatten(treedef, specs)


def shardings_of(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh) -> P:
    return P(_dp(mesh), None)


def activation_constraint(mesh, layout: str = "sp"):
    """Residual-stream constraint between layers.

    layout="sp"    — batch over DP, sequence over model (Megatron-SP): best
                     for the chunked-attention baseline (stored activations
                     seq/|model| per device).
    layout="dmodel"— batch over DP, d_model over model: keeps the flash
                     path's (S -> tiles) reshapes shard-local (reshaping an
                     SP-sharded sequence axis forces XLA to re-shard every
                     tile — the §Perf iteration-2 diagnosis)."""
    dp = _dp(mesh)
    spec = P(dp, "model", None) if layout == "sp" else P(dp, None, "model")

    def f(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return f


def cache_specs(cfg: ModelConfig, caches_tree, mesh, *, shard_sequence: bool):
    """KV/recurrent cache shardings for serve steps.

    shard_sequence=False (batched decode, e.g. decode_32k): batch over DP,
    sequence over ``model`` (flash-decoding split-K layout — per-device cache
    = total / (|dp|·|model|), head-count agnostic so MQA archs shard too).
    shard_sequence=True (batch-1 long context): sequence over EVERY axis.
    Stacked cache leaves look like (n, B, S, Hkv, Dh) for kv; (n, B, NCB,
    Hkv, Dh) for cmp; recurrent states (n, B, ...).
    """
    dp = _dp(mesh)

    def rule(path, leaf):
        key = _path_key(path)
        if key.endswith("length"):
            return P()
        nd = len(leaf.shape)
        if "state" in key:  # recurrent state (n, B, ...): batch over DP
            if shard_sequence:  # batch-1 long-context: states are tiny; replicate
                return P(*([None] * nd))
            return P(*((None, dp) + (None,) * (nd - 2)))
        # kv / cmp caches: (n, B, S|NCB, Hkv, Dh)
        if nd == 5:
            if shard_sequence:
                return P(None, None, dp + ("model",), None, None)
            return P(None, dp, "model", None, None)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(p, l) for p, l in flat])
